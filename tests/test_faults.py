"""Chaos harness for the fault-injection and recovery layer (§10).

The contract under test:

  * **recoverable faults are invisible**: a run under injected
    transient step failures / forced mid-run OOM / latency spikes
    produces tokens, log-weights, and log-evidence **bit-identical** to
    the fault-free run (rollback-retry restores the pre-tick snapshot,
    RNG keys included);
  * **unrecoverable faults surface typed**, with the pool
    invariant-clean: retry exhaustion raises
    :class:`FaultRetriesExhausted`, device loss raises
    :class:`DeviceLost`, and ``check_invariants()`` is empty afterward;
  * **nothing hangs and nothing silently drops**: cancel / deadline /
    quarantine / load-shed all end in a typed
    ``SMCDecodeResult.status``, pages freed, the rest of the batch
    bit-exact;
  * **crash consistency**: ``checkpoint()`` -> kill -> ``restore()`` in
    a fresh engine resumes bit-exactly (the kill-and-restore
    differential);
  * **the simulator mirrors it all**: chaos runs replay decision-exact
    through ``serving/sim.py``, including the committed regression
    corpus in tests/chaos_corpus/.
"""

from __future__ import annotations

import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.model import LanguageModel
from repro.serving.engine import ServeEngine
from repro.serving.faults import (
    DeviceLost,
    FaultEvent,
    FaultInjector,
    FaultKind,
    FaultRetriesExhausted,
    RequestStatus,
    RetryPolicy,
    chaos_schedule,
    schedule_from_json,
    schedule_to_json,
)
from repro.serving.kv_cache import KVCacheConfig
from repro.serving.scheduler import (
    DecodeRequest,
    Scheduler,
    SchedulerEventLog,
    load_checkpoint,
    save_checkpoint,
)
from repro.serving.sim import CostModel, first_divergence, simulate

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare CI hosts
    HAVE_HYPOTHESIS = False


def seeded_property(max_examples: int = 10, fallback_seeds: int = 5):
    """@given(seed) under hypothesis, a seeded parametrize without."""

    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=max_examples, deadline=None)(
                given(seed=st.integers(0, 10_000))(fn)
            )
        return pytest.mark.parametrize("seed", range(fallback_seeds))(fn)

    return deco


KEY = jax.random.PRNGKey(0)
BS = 4
CORPUS_DIR = os.path.join(os.path.dirname(__file__), "chaos_corpus")

COST = CostModel(
    step_s=1e-3, prefill_s=2e-3, grow_s_per_block=1e-5, compact_s_per_block=1e-5
)


@pytest.fixture(scope="module")
def model():
    cfg = smoke_config("musicgen_large")
    lm = LanguageModel(cfg)
    params, _ = lm.init(KEY)
    return cfg, lm, params


def make_engine(model, max_seqs, num_blocks=0, max_blocks_per_seq=24):
    cfg, lm, params = model
    ccfg = KVCacheConfig(
        n_layers=cfg.n_layers,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd,
        block_size=BS,
        max_seqs=max_seqs,
        max_blocks_per_seq=max_blocks_per_seq,
        num_blocks=num_blocks,
        dtype=cfg.dtype,
    )
    return ServeEngine(lm, params, ccfg)


def make_request(model, rid, seed, n, steps, plen, arrive_at=0, deadline=None):
    cfg, _, _ = model
    return DecodeRequest(
        rid=rid,
        prompt=jax.random.randint(
            jax.random.PRNGKey(seed), (plen,), 0, cfg.vocab_size
        ),
        n_particles=n,
        steps=steps,
        key=jax.random.PRNGKey(100 + seed),
        target_temp=0.5,
        token_block_size=BS,
        arrive_at=arrive_at,
        deadline=deadline,
    )


def run_sched(model, reqs, engine_kw, hook=None, **sched_kw):
    eng = make_engine(model, **engine_kw)
    sched = Scheduler(eng, on_boundary=hook, **sched_kw)
    for r in reqs:
        sched.submit(r)
    results = sched.run()
    return sched, results


def assert_bit_exact(res_a, res_b):
    np.testing.assert_array_equal(np.asarray(res_a.tokens), np.asarray(res_b.tokens))
    np.testing.assert_array_equal(
        np.asarray(res_a.log_weights), np.asarray(res_b.log_weights)
    )
    np.testing.assert_array_equal(
        np.asarray(res_a.log_evidence), np.asarray(res_b.log_evidence)
    )


# -- the injector itself (no model) ------------------------------------------


class TestFaultInjector:
    def test_consumption_and_repeats(self):
        inj = FaultInjector(
            [
                FaultEvent(FaultKind.STEP_FAILURE, tick=2, repeats=2),
                FaultEvent(FaultKind.NAN_LOGITS, tick=2, rid="a"),
            ]
        )
        assert inj.step_events(0) == []  # off-tick attempts consume nothing
        evs = inj.step_events(2)  # attempt 1: both fire
        assert [e.kind for e in evs] == [
            FaultKind.STEP_FAILURE,
            FaultKind.NAN_LOGITS,
        ]
        evs = inj.step_events(2)  # attempt 2: only the repeats=2 failure
        assert [e.kind for e in evs] == [FaultKind.STEP_FAILURE]
        assert inj.step_events(2) == []  # spent
        assert inj.fired == 3

    def test_reset_replays(self):
        inj = FaultInjector([FaultEvent(FaultKind.OOM, tick=1)])
        assert len(inj.step_events(1)) == 1
        fresh = inj.reset()
        assert fresh.schedule == inj.schedule
        assert len(fresh.step_events(1)) == 1

    def test_chaos_schedule_deterministic(self):
        kw = dict(
            rate=0.5, rids=("a", "b"), p_poison=0.3, delay_s=0.01, max_repeats=3
        )
        s1 = chaos_schedule(42, 20, **kw)
        s2 = chaos_schedule(42, 20, **kw)
        assert s1 == s2
        assert s1 != chaos_schedule(43, 20, **kw)
        assert any(ev.kind is FaultKind.NAN_LOGITS for ev in s1)

    def test_schedule_json_round_trip(self):
        sched = chaos_schedule(3, 15, rate=0.4, rids=("x",), p_poison=0.2)
        assert schedule_from_json(schedule_to_json(sched)) == sched

    def test_retry_backoff_capped(self):
        rp = RetryPolicy(max_retries=5, backoff_base_s=0.1, backoff_cap_s=0.3)
        assert [rp.delay_s(a) for a in (1, 2, 3, 4)] == [
            0.1,
            0.2,
            0.3,
            0.3,
        ]
        assert RetryPolicy().delay_s(3) == 0.0  # default never sleeps


# -- recoverable faults are bit-invisible ------------------------------------


class TestRecovery:
    def clean(self, model, reqs, engine_kw, **kw):
        _, results = run_sched(model, reqs, engine_kw, **kw)
        return results

    def test_step_failure_bit_exact(self, model):
        reqs = lambda: [  # noqa: E731
            make_request(model, "a", 1, n=6, steps=8, plen=6),
            make_request(model, "b", 2, n=4, steps=10, plen=9),
        ]
        ref = self.clean(model, reqs(), dict(max_seqs=10))
        inj = FaultInjector(
            [
                FaultEvent(FaultKind.STEP_FAILURE, tick=2, repeats=2),
                FaultEvent(FaultKind.STEP_FAILURE, tick=7),
            ]
        )
        sched, results = run_sched(model, reqs(), dict(max_seqs=10), faults=inj)
        for rid in ("a", "b"):
            assert results[rid].status == "ok"
            assert_bit_exact(results[rid], ref[rid])
        assert sched.stats.faults == 3
        assert sched.stats.retries == 3
        assert sched.check_invariants() == []

    def test_forced_oom_bit_exact_and_invariant_clean(self, model):
        req = make_request(model, "a", 3, n=6, steps=8, plen=6)
        ref = self.clean(
            model, [make_request(model, "a", 3, n=6, steps=8, plen=6)],
            dict(max_seqs=8),
        )
        inj = FaultInjector([FaultEvent(FaultKind.OOM, tick=3)])
        sched, results = run_sched(
            model, [req], dict(max_seqs=8), faults=inj, watchdog=True
        )
        assert_bit_exact(results["a"], ref["a"])
        # The forced starvation set the sticky oom flag mid-attempt; the
        # rollback must have restored the clean pool (flag included) or
        # the result would report oom and the watchdog would have fired.
        assert not bool(results["a"].oom)
        assert sched.check_invariants() == []

    def test_latency_spike_only_slows(self, model):
        req = make_request(model, "a", 4, n=4, steps=6, plen=4)
        ref = self.clean(
            model, [make_request(model, "a", 4, n=4, steps=6, plen=4)],
            dict(max_seqs=6),
        )
        log = SchedulerEventLog()
        inj = FaultInjector([FaultEvent(FaultKind.LATENCY, tick=2, delay_s=0.05)])
        sched, results = run_sched(
            model, [req], dict(max_seqs=6), faults=inj, event_log=log
        )
        assert_bit_exact(results["a"], ref["a"])
        assert sched.stats.retries == 0  # latency is not an error
        assert max(log.step_wall_s) >= 0.05  # the spike is on the record

    def test_retries_exhausted_surfaces_typed(self, model):
        req = make_request(model, "a", 5, n=4, steps=6, plen=4)
        inj = FaultInjector([FaultEvent(FaultKind.STEP_FAILURE, tick=1, repeats=5)])
        eng = make_engine(model, max_seqs=6)
        sched = Scheduler(eng, faults=inj, retry_policy=RetryPolicy(max_retries=2))
        sched.submit(req)
        with pytest.raises(FaultRetriesExhausted) as exc:
            sched.run()
        assert exc.value.tick == 1
        assert exc.value.attempts == 3  # 1 try + 2 retries
        # State restored to the pre-tick snapshot: invariant-clean, the
        # request still live and resumable.
        assert sched.check_invariants() == []
        assert [s.req.rid for s in sched._active] == ["a"]

    def test_device_loss_raises_before_mutation(self, model):
        req = make_request(model, "a", 6, n=4, steps=6, plen=4)
        inj = FaultInjector([FaultEvent(FaultKind.DEVICE_LOSS, tick=2)])
        eng = make_engine(model, max_seqs=6)
        sched = Scheduler(eng, faults=inj)
        sched.submit(req)
        with pytest.raises(DeviceLost):
            sched.run()
        assert sched.check_invariants() == []


# -- quarantine, cancel, deadline, shed --------------------------------------


class TestTypedTerminations:
    def test_nan_quarantine_isolates_one_request(self, model):
        reqs = lambda: [  # noqa: E731
            make_request(model, "a", 1, n=6, steps=8, plen=6),
            make_request(model, "b", 2, n=4, steps=10, plen=9),
        ]
        ref = dict(run_sched(model, reqs(), dict(max_seqs=10))[1])
        inj = FaultInjector([FaultEvent(FaultKind.NAN_LOGITS, tick=3, rid="a")])
        sched, results = run_sched(
            model, reqs(), dict(max_seqs=10), faults=inj, watchdog=True
        )
        assert results["a"].status == RequestStatus.POISONED.value
        # The poisoned population kept its clean prefix (the tick's
        # token was sampled from pre-poison logits), zero-padded beyond.
        toks = np.asarray(results["a"].tokens)
        np.testing.assert_array_equal(
            toks[:, :4], np.asarray(ref["a"].tokens)[:, :4]
        )
        assert (toks[:, 4:] == 0).all()
        # The co-resident request never noticed.
        assert results["b"].status == "ok"
        assert_bit_exact(results["b"], ref["b"])
        assert sched.stats.poisoned == 1
        assert sched.check_invariants() == []

    def test_cancel_mid_flight(self, model):
        reqs = lambda: [  # noqa: E731
            make_request(model, "a", 1, n=6, steps=8, plen=6),
            make_request(model, "b", 2, n=4, steps=10, plen=9),
        ]
        ref = run_sched(model, reqs(), dict(max_seqs=10))[1]
        fired = []

        def hook(sched):
            if sched.tick >= 3 and not fired:
                fired.append(True)
                sched.cancel("a")

        sched, results = run_sched(
            model, reqs(), dict(max_seqs=10), hook=hook, watchdog=True
        )
        assert results["a"].status == RequestStatus.CANCELLED.value
        assert results["b"].status == "ok"
        assert_bit_exact(results["b"], ref["b"])
        assert sched.stats.cancelled == 1
        assert sched.slots.used == 0
        with pytest.raises(KeyError):
            sched.cancel("a")  # no longer live

    def test_deadline_expires_active_request(self, model):
        req = make_request(model, "a", 7, n=4, steps=20, plen=4, deadline=5)
        sched, results = run_sched(model, [req], dict(max_seqs=6))
        assert results["a"].status == RequestStatus.EXPIRED.value
        toks = np.asarray(results["a"].tokens)
        assert (toks[:, 5:] == 0).all()  # nothing decoded past the SLA
        assert sched.stats.expired == 1

    def test_deadline_unblocks_head_of_line(self, model):
        # "long" holds 4 of 6 slots; "big" (4 slots) can't join while
        # it runs and, as FIFO head, blocks "small" (2 slots) that
        # *would* fit.  big's deadline expires it from the queue and
        # small completes long before long does.
        reqs = [
            make_request(model, "long", 8, n=4, steps=14, plen=4),
            make_request(
                model, "big", 9, n=4, steps=8, plen=4, arrive_at=1, deadline=4
            ),
            make_request(model, "small", 10, n=2, steps=4, plen=4, arrive_at=1),
        ]
        sched, results = run_sched(model, reqs, dict(max_seqs=6))
        assert results["big"].status == RequestStatus.EXPIRED.value
        assert results["small"].status == "ok"
        assert results["long"].status == "ok"
        # small departed before long: the expired head stopped blocking.
        assert sched.stats.expired == 1

    def test_shed_policy_bounds_queue(self, model):
        # Four burst arrivals onto a 4-slot engine: one runs, one may
        # wait, the rest shed newest-first.
        reqs = [
            make_request(model, f"r{i}", 10 + i, n=4, steps=6, plen=4)
            for i in range(4)
        ]
        sched, results = run_sched(
            model,
            reqs,
            dict(max_seqs=4),
            admission="shed",
            queue_limit=1,
        )
        statuses = {rid: r.status for rid, r in results.items()}
        assert statuses["r0"] == "ok"
        assert statuses["r1"] == "ok"  # the one bounded waiter
        assert statuses["r2"] == RequestStatus.SHED.value
        assert statuses["r3"] == RequestStatus.SHED.value
        assert sched.stats.shed == 2

    def test_unknown_admission_policy_rejected(self, model):
        with pytest.raises(ValueError, match="admission"):
            Scheduler(make_engine(model, max_seqs=4), admission="lifo")


# -- crash consistency: checkpoint / kill / restore --------------------------


class TestCheckpointRestore:
    def test_kill_and_restore_bit_exact(self, model, tmp_path):
        reqs = lambda: [  # noqa: E731
            make_request(model, "a", 1, n=6, steps=8, plen=6),
            make_request(model, "b", 2, n=4, steps=10, plen=9),
        ]
        ref = run_sched(model, reqs(), dict(max_seqs=10))[1]

        # Run until tick 4, checkpoint at that boundary, then "crash"
        # (abandon the scheduler object entirely).
        class Kill(Exception):
            pass

        saved = {}

        def hook(sched):
            if sched.tick == 4 and not saved:
                saved["state"] = sched.checkpoint()
                raise Kill

        eng = make_engine(model, max_seqs=10)
        sched = Scheduler(eng, on_boundary=hook)
        for r in reqs():
            sched.submit(r)
        with pytest.raises(Kill):
            sched.run()

        # Through-disk round trip, then a fresh engine (fresh process
        # stand-in: nothing shared but the params).
        path = tmp_path / "sched.ckpt"
        save_checkpoint(path, saved["state"])
        state = load_checkpoint(path)
        eng2 = make_engine(model, max_seqs=10)
        sched2 = Scheduler.restore(eng2, state, watchdog=True)
        results = sched2.run()
        for rid in ("a", "b"):
            assert results[rid].status == "ok"
            assert_bit_exact(results[rid], ref[rid])
        assert sched2.check_invariants() == []

    def test_device_loss_then_restore_last_checkpoint(self, model):
        reqs = lambda: [make_request(model, "a", 3, n=4, steps=8, plen=4)]  # noqa: E731
        ref = run_sched(model, reqs(), dict(max_seqs=6))[1]
        last = {}

        def hook(sched):
            last["state"] = sched.checkpoint()

        inj = FaultInjector([FaultEvent(FaultKind.DEVICE_LOSS, tick=5)])
        eng = make_engine(model, max_seqs=6)
        sched = Scheduler(eng, on_boundary=hook, faults=inj)
        for r in reqs():
            sched.submit(r)
        with pytest.raises(DeviceLost):
            sched.run()
        # The device is gone; a fresh engine restores the last boundary
        # checkpoint and finishes bit-exactly.
        eng2 = make_engine(model, max_seqs=6)
        sched2 = Scheduler.restore(eng2, last["state"])
        results = sched2.run()
        assert_bit_exact(results["a"], ref["a"])

    def test_restore_rejects_mismatched_engine(self, model):
        eng = make_engine(model, max_seqs=6)
        sched = Scheduler(eng)
        state = sched.checkpoint()
        with pytest.raises(ValueError, match="cache config"):
            Scheduler.restore(make_engine(model, max_seqs=8), state)


# -- the simulator mirrors chaos runs decision-exactly -----------------------


def record_and_replay_chaos(model, reqs, engine_kw, schedule, **sched_kw):
    eng = make_engine(model, **engine_kw)
    log = SchedulerEventLog()
    sched = Scheduler(eng, event_log=log, faults=FaultInjector(schedule), **sched_kw)
    for r in reqs:
        sched.submit(r)
    sched.run()
    res = simulate(
        log.to_trace("chaos"),
        eng.cache_cfg,
        COST,
        faults=FaultInjector(schedule),
        **sched_kw,
    )
    return log, res, sched


class TestChaosDifferential:
    def check(self, log, res, sched):
        div = first_divergence(log.decisions, res.decisions)
        assert div is None, div
        assert res.stats.as_dict() == sched.stats.as_dict()

    def test_recoverable_chaos_replays(self, model):
        reqs = [
            make_request(model, "a", 1, n=6, steps=8, plen=6),
            make_request(model, "b", 2, n=4, steps=10, plen=9, arrive_at=3),
        ]
        schedule = [
            FaultEvent(FaultKind.STEP_FAILURE, tick=2, repeats=2),
            FaultEvent(FaultKind.LATENCY, tick=4, delay_s=0.001),
            FaultEvent(FaultKind.OOM, tick=6),
        ]
        log, res, sched = record_and_replay_chaos(
            model, reqs, dict(max_seqs=10), schedule
        )
        self.check(log, res, sched)

    def test_poison_and_deadline_chaos_replays(self, model):
        reqs = [
            make_request(model, "a", 3, n=6, steps=10, plen=6),
            make_request(model, "b", 4, n=4, steps=12, plen=4, deadline=8),
        ]
        schedule = [FaultEvent(FaultKind.NAN_LOGITS, tick=5, rid="a")]
        log, res, sched = record_and_replay_chaos(
            model, reqs, dict(max_seqs=10), schedule
        )
        self.check(log, res, sched)
        assert sched._results["a"].status == RequestStatus.POISONED.value
        assert res.requests["a"]["status"] == RequestStatus.POISONED.value

    @seeded_property(max_examples=5, fallback_seeds=3)
    def test_seeded_chaos_replays(self, model, seed):
        schedule = chaos_schedule(
            seed,
            12,
            rate=0.3,
            rids=("a", "b"),
            p_poison=0.1,
            max_repeats=2,
        )
        reqs = [
            make_request(model, "a", seed, n=4, steps=8, plen=4),
            make_request(model, "b", seed + 1, n=4, steps=6, plen=6, arrive_at=2),
        ]
        log, res, sched = record_and_replay_chaos(
            model, reqs, dict(max_seqs=10), schedule
        )
        self.check(log, res, sched)


class TestChaosCorpus:
    """Committed chaos regressions: each corpus file pins a seeded
    schedule (regenerated and byte-compared — the generator may not
    drift) and must replay decision-exact real-vs-sim."""

    def load(self):
        files = sorted(glob.glob(os.path.join(CORPUS_DIR, "*.json")))
        assert files, "chaos corpus is missing"
        return [json.load(open(f)) for f in files]

    def test_schedules_pinned(self):
        for spec in self.load():
            regen = chaos_schedule(
                spec["seed"], spec["ticks"], **spec["schedule_kwargs"]
            )
            assert schedule_from_json(json.dumps(spec["schedule"])) == regen, (
                f"corpus {spec['name']!r} drifted from its generator"
            )

    def test_corpus_replays_decision_exact(self, model):
        for spec in self.load():
            reqs = [make_request(model, **r) for r in spec["requests"]]
            schedule = schedule_from_json(json.dumps(spec["schedule"]))
            log, res, sched = record_and_replay_chaos(
                model, reqs, spec["engine"], schedule, **spec.get("knobs", {})
            )
            div = first_divergence(log.decisions, res.decisions)
            assert div is None, f"corpus {spec['name']!r}: {div}"
            assert res.stats.as_dict() == sched.stats.as_dict()
            for rid, r in res.requests.items():
                assert sched._results[rid].status == r["status"]


# -- property tests: lifecycle interleavings keep the invariants -------------


class TestLifecycleProperties:
    @seeded_property(max_examples=8, fallback_seeds=4)
    def test_interleaved_ops_keep_invariants(self, model, seed):
        """Random interleavings of preempt / cancel / grow-pressure at
        every boundary, with the watchdog on: the run must end with all
        requests typed and the conservation laws intact (the watchdog
        itself raises on the first corrupted boundary)."""
        rng = np.random.default_rng(seed)
        reqs = [
            make_request(
                model,
                f"r{i}",
                int(rng.integers(1, 1000)),
                n=int(rng.integers(2, 6)),
                steps=int(rng.integers(4, 9)),
                plen=int(rng.integers(3, 7)),
                arrive_at=int(rng.integers(0, 4)),
                deadline=(
                    None if rng.random() < 0.6 else int(rng.integers(3, 12))
                ),
            )
            for i in range(3)
        ]

        def hook(sched):
            if not sched._active:
                return
            r = rng.random()
            victim = sched._active[int(rng.integers(len(sched._active)))]
            if r < 0.15 and len(sched._active) > 1:
                sched.preempt(victim.req.rid)
            elif r < 0.25:
                sched.cancel(victim.req.rid)

        sched, results = run_sched(
            model,
            reqs,
            dict(max_seqs=10),
            hook=hook,
            watchdog=True,
        )
        assert sched.check_invariants() == []
        assert set(results) == {r.rid for r in reqs}
        for res in results.values():
            assert res.status in {s.value for s in RequestStatus}
        assert sched.slots.used == 0
