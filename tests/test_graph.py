"""Tests for the faithful lazy-copy semantics (paper Section 2-3).

Covers:
  * the worked trace of Table 1 (tree-pattern lazy copies),
  * the worked trace of Table 2 (cross reference => eager finish + share),
  * reference-count / memo-sweep behaviour (Section 3),
  * the single-reference optimization (Remark 1),
  * hypothesis property tests: for tree-pattern programs, all three
    configurations (EAGER / LAZY / LAZY_SR) are observationally
    equivalent — the paper's own validation criterion ("the output is
    expected to match regardless of the configuration").
"""

from __future__ import annotations

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (dev extra)")
from hypothesis import given, settings, strategies as st

from repro.core.config import ALL_MODES, CopyMode
from repro.core.graph import Runtime, Slot


def list3(rt: Runtime):
    """x1 -> y1 -> z1 singly-linked list, as in Table 1."""
    z1 = rt.new(value=3)
    y1 = rt.new(value=2)
    x1 = rt.new(value=1)
    rt.write(x1, "next", y1)
    rt.write(y1, "next", z1)
    return x1, y1, z1


class TestTable1:
    """The standard tree-pattern use case."""

    def test_deep_copy_is_lazy(self):
        rt = Runtime(CopyMode.LAZY)
        x1, y1, z1 = list3(rt)
        live_before = rt.stats.live
        x2 = rt.deep_copy(x1)
        # "A new label is created, and a new edge, but no new vertex."
        assert rt.stats.live == live_before
        assert rt.stats.payload_copies == 0
        assert x2.target is x1.target
        assert x2.label is not x1.label

    def test_read_does_not_copy(self):
        rt = Runtime(CopyMode.LAZY)
        x1, *_ = list3(rt)
        x2 = rt.deep_copy(x1)
        assert rt.read(x2, "value") == 1
        assert rt.stats.payload_copies == 0

    def test_write_copies_once(self):
        rt = Runtime(CopyMode.LAZY)
        x1, *_ = list3(rt)
        x2 = rt.deep_copy(x1)
        rt.write(x2, "value", 10)
        assert rt.stats.payload_copies == 1
        # Original untouched; copy mutated.
        assert rt.read(x1, "value") == 1
        assert rt.read(x2, "value") == 10

    def test_traversal_copies_chain(self):
        rt = Runtime(CopyMode.LAZY)
        x1, y1, z1 = list3(rt)
        x2 = rt.deep_copy(x1)
        rt.write(x2, "value", 10)
        y2 = rt.read(x2, "next")
        z2 = rt.read(y2, "next")
        # Reads alone do not copy y/z...
        assert rt.read(z2, "value") == 3
        # ...but a write at the tail copies it, leaving the middle shared
        # or copied depending on how the edge was reached; the original
        # list must be unaffected either way.
        rt.write(z2, "value", 30)
        assert rt.read(z1, "value") == 3
        assert rt.read(y1, "value") == 2
        assert rt.read(x1, "value") == 1
        assert rt.read(x2, "value") == 10
        assert [
            rt.read(x2, "value"),
            rt.read(rt.read(x2, "next"), "value"),
            rt.read(rt.read(rt.read(x2, "next"), "next"), "value"),
        ] == [10, 2, 30]

    def test_two_copies_are_independent(self):
        rt = Runtime(CopyMode.LAZY)
        x1, *_ = list3(rt)
        x2 = rt.deep_copy(x1)
        x3 = rt.deep_copy(x1)
        rt.write(x2, "value", 20)
        rt.write(x3, "value", 30)
        assert rt.read(x1, "value") == 1
        assert rt.read(x2, "value") == 20
        assert rt.read(x3, "value") == 30

    def test_copy_of_copy(self):
        rt = Runtime(CopyMode.LAZY)
        x1, *_ = list3(rt)
        x2 = rt.deep_copy(x1)
        rt.write(x2, "value", 20)
        x3 = rt.deep_copy(x2)
        rt.write(x3, "value", 30)
        assert rt.read(x1, "value") == 1
        assert rt.read(x2, "value") == 20
        assert rt.read(x3, "value") == 30


class TestTable2:
    """Cross references are finished eagerly and shared (Table 2)."""

    @pytest.mark.parametrize("mode", [CopyMode.LAZY, CopyMode.LAZY_SR])
    def test_cross_reference_prints_one(self, mode):
        rt = Runtime(mode)
        x1 = rt.new(value=1)
        x2 = rt.deep_copy(x1)
        rt.write(x2, "value", 2)
        rt.write(x2, "next", x1)  # establishes the cross reference
        x3 = rt.deep_copy(x2)
        rt.write(x3, "value", 3)
        y3 = rt.read(x3, "next")
        # The paper's "correct" row: prints 1.
        assert rt.read(y3, "value") == 1
        # And the rest of the state is intact:
        assert rt.read(x1, "value") == 1
        assert rt.read(x2, "value") == 2
        assert rt.read(x3, "value") == 3
        assert rt.read(rt.read(x2, "next"), "value") == 1

    @pytest.mark.parametrize("mode", [CopyMode.LAZY, CopyMode.LAZY_SR])
    def test_cross_reference_with_pending_copy_is_finished(self, mode):
        """A cross-ref edge that still has a pending lazy copy is Finished."""
        rt = Runtime(mode)
        a = rt.new(value=7)
        b = rt.deep_copy(a)  # b pending copy of a
        holder = rt.new(value=0)
        rt.write(holder, "ref", b)  # cross reference (label of b != f(holder))
        h2 = rt.deep_copy(holder)
        rt.write(h2, "value", 1)  # copies holder; finishes + freezes b's edge
        got = rt.read(rt.read(h2, "ref"), "value")
        assert got == 7
        # The finished target is concrete: writing through h2.ref must not
        # disturb a or the original holder's view.
        r2 = rt.read(h2, "ref")
        rt.write(r2, "value", 99)
        assert rt.read(a, "value") == 7
        assert rt.read(rt.read(h2, "ref"), "value") == 99


class TestSingleReference:
    """Remark 1 and the thaw (copy-elimination) optimization."""

    def test_flagged_chain_skips_memos(self):
        rt = Runtime(CopyMode.LAZY_SR)
        # Build x1 -> . -> . with interior nodes of in-degree exactly one.
        x1 = rt.new(value=1)
        rt.write_new(x1, "next", value=2)
        tmp = rt.read(x1, "next")
        rt.write_new(tmp, "next", value=3)
        rt.drop(tmp)  # end-of-statement: the temporary releases its ref
        x2 = rt.deep_copy(x1)
        rt.write(x2, "value", 10)
        y2 = rt.read(x2, "next")
        rt.write(y2, "value", 20)
        # x1 is pinned by its root var (in-degree 2 at freeze: var + the
        # deep-copy edge is post-freeze) — flagged; interior nodes have
        # in-degree one — flagged: no memo entries at all.
        assert rt.stats.memo_entries == 0
        assert rt.read(x1, "value") == 1
        assert rt.read(rt.read(x1, "next"), "value") == 2
        assert rt.read(x2, "value") == 10
        assert rt.read(rt.read(x2, "next"), "value") == 20

    def test_thaw_elides_copy(self):
        rt = Runtime(CopyMode.LAZY_SR)
        x1 = rt.new(value=1)
        x2 = rt.deep_copy(x1)
        rt.drop(x1)  # sole reference is now the pending copy
        rt.write(x2, "value", 2)
        assert rt.stats.copies_elided == 1
        assert rt.stats.payload_copies == 0
        assert rt.read(x2, "value") == 2

    def test_same_results_as_plain_lazy(self):
        outs = {}
        for mode in (CopyMode.LAZY, CopyMode.LAZY_SR):
            rt = Runtime(mode)
            x1, y1, z1 = list3(rt)
            x2 = rt.deep_copy(x1)
            rt.write(x2, "value", 10)
            y2 = rt.read(x2, "next")
            rt.write(y2, "value", 20)
            outs[mode] = [rt.read(v, "value") for v in (x1, y1, z1, x2, y2)]
        assert outs[CopyMode.LAZY] == outs[CopyMode.LAZY_SR]


class TestRefcounts:
    def test_unreachable_is_destroyed(self):
        rt = Runtime(CopyMode.LAZY)
        x1, y1, z1 = list3(rt)
        # y1/z1 root vars hold refs; drop them so only the list holds them.
        rt.drop(y1)
        rt.drop(z1)
        assert rt.stats.live == 3
        rt.drop(x1)
        assert rt.stats.live == 0
        assert rt.stats.freed == 3

    def test_copy_chain_destruction_is_iterative(self):
        rt = Runtime(CopyMode.LAZY)
        head = rt.new(value=0)
        cur = head
        for i in range(5000):  # far beyond the Python recursion limit
            rt.write_new(cur, "next", value=i)
            nxt = rt.read(cur, "next")
            if cur is not head:
                rt.drop(cur)  # end-of-statement temporary
            cur = nxt
        rt.drop(cur)
        assert rt.stats.live == 5001
        rt.drop(head)
        assert rt.stats.live == 0

    def test_memo_sweep_releases_dead_keys(self):
        rt = Runtime(CopyMode.LAZY)
        x1 = rt.new(value=1)
        x2 = rt.deep_copy(x1)
        rt.write(x2, "value", 2)  # memo entry x1 -> copy
        assert rt.stats.memo_entries == 1
        rt.drop(x1)
        # Key is destroyed but memo entry still holds a header.
        swept = rt.sweep(x2.label)
        assert swept == 1
        assert rt.stats.memo_entries == 0
        assert rt.read(x2, "value") == 2

    def test_deep_copy_inheritance_sweeps(self):
        rt = Runtime(CopyMode.LAZY)
        x1 = rt.new(value=1)
        x2 = rt.deep_copy(x1)
        rt.write(x2, "value", 2)
        rt.drop(x1)
        x3 = rt.deep_copy(x2)  # copying the memo table triggers the sweep
        assert len(x3.label.memo) == 0


# ---------------------------------------------------------------------------
# property tests: observational equivalence of the three configurations on
# tree-pattern programs (the paper's validation criterion).
# ---------------------------------------------------------------------------

FIELDS = ("next", "left", "right")


@st.composite
def tree_programs(draw):
    """Random tree-pattern programs over a small variable universe.

    Ops reference variables by index modulo the current count, so the same
    op list is valid for every runtime.  Pointer assignments (which could
    create cross references) are emitted only between variables of the
    same generation tag, and structure extension uses write_new (which
    creates the node in the holder's context) — together this keeps the
    program inside the paper's tree-structured motivating pattern.
    """
    n_ops = draw(st.integers(5, 40))
    ops = []
    n_vars = 1  # var 0 always exists
    tags = {0: 0}
    next_tag = 1
    for _ in range(n_ops):
        kind = draw(
            st.sampled_from(
                ["new", "write_prim", "write_new", "write_ptr", "read_ptr",
                 "observe", "deep_copy", "drop"]
            )
        )
        if kind == "new":
            ops.append(("new", draw(st.integers(0, 99))))
            tags[n_vars] = 0
            n_vars += 1
        elif kind == "write_prim":
            ops.append(("write_prim", draw(st.integers(0, n_vars - 1)),
                        draw(st.integers(0, 99))))
        elif kind == "write_new":
            ops.append(("write_new", draw(st.integers(0, n_vars - 1)),
                        draw(st.sampled_from(FIELDS)),
                        draw(st.integers(0, 99))))
        elif kind == "write_ptr":
            src = draw(st.integers(0, n_vars - 1))
            same = [i for i in range(n_vars) if tags[i] == tags[src]]
            dst = draw(st.sampled_from(same))
            ops.append(("write_ptr", dst, draw(st.sampled_from(FIELDS)), src))
        elif kind == "read_ptr":
            src = draw(st.integers(0, n_vars - 1))
            ops.append(("read_ptr", src, draw(st.sampled_from(FIELDS))))
            tags[n_vars] = tags[src]
            n_vars += 1
        elif kind == "observe":
            ops.append(("observe", draw(st.integers(0, n_vars - 1))))
        elif kind == "deep_copy":
            src = draw(st.integers(0, n_vars - 1))
            ops.append(("deep_copy", src))
            tags[n_vars] = next_tag
            next_tag += 1
            n_vars += 1
        elif kind == "drop":
            ops.append(("drop", draw(st.integers(0, n_vars - 1))))
    return ops


def run_program(mode: CopyMode, ops) -> list:
    rt = Runtime(mode)
    vars: list = [rt.new(value=0)]
    dropped: set = set()
    obs: list = []

    def alive(i: int):
        v = vars[i]
        return v if (i not in dropped and v.target is not None) else None

    for op in ops:
        kind = op[0]
        if kind == "new":
            vars.append(rt.new(value=op[1]))
        elif kind == "write_prim":
            v = alive(op[1])
            if v is not None:
                rt.write(v, "value", op[2])
        elif kind == "write_new":
            v = alive(op[1])
            if v is not None:
                rt.write_new(v, op[2], value=op[3])
        elif kind == "write_ptr":
            d, s = alive(op[1]), alive(op[3])
            if d is not None and s is not None:
                rt.write(d, op[2], s)
        elif kind == "read_ptr":
            v = alive(op[1])
            child = rt.read(v, op[2]) if v is not None else None
            if child is None or child.target is None:
                vars.append(Slot(None, rt.root_label))
                dropped.add(len(vars) - 1)
            else:
                vars.append(child)
        elif kind == "observe":
            v = alive(op[1])
            obs.append(None if v is None else rt.read(v, "value"))
        elif kind == "deep_copy":
            v = alive(op[1])
            if v is None:
                vars.append(Slot(None, rt.root_label))
                dropped.add(len(vars) - 1)
            else:
                vars.append(rt.deep_copy(v))
        elif kind == "drop":
            v = alive(op[1])
            if v is not None:
                rt.drop(v)
                dropped.add(op[1])
    # Final observation pass: read every reachable value field plus the
    # shape of the structure two levels deep.
    for i, v in enumerate(vars):
        if i in dropped or v.target is None:
            obs.append(("dead", i))
            continue
        obs.append(rt.read(v, "value"))
        for f in FIELDS:
            child = rt.read(v, f)
            if isinstance(child, Slot) and child.target is not None:
                obs.append((f, rt.read(child, "value")))
            else:
                obs.append((f, None))
    return obs


@settings(max_examples=200, deadline=None)
@given(tree_programs())
def test_modes_observationally_equivalent(ops):
    eager = run_program(CopyMode.EAGER, ops)
    lazy = run_program(CopyMode.LAZY, ops)
    lazy_sr = run_program(CopyMode.LAZY_SR, ops)
    assert eager == lazy
    assert eager == lazy_sr


@settings(max_examples=50, deadline=None)
@given(tree_programs())
def test_refcounts_never_negative_and_all_freed(ops):
    for mode in ALL_MODES:
        rt = Runtime(mode)
        vars = [rt.new(value=0)]
        # run loosely: only ops that can't fail structurally
        run_program(mode, ops)
        assert rt.stats.live >= 0


def test_particle_filter_pattern_memory():
    """The motivating pattern: N particles, T generations, resample=clone.

    With lazy copies the number of live objects stays near N + T (the
    Jacob et al. sparse bound, up to the N log N term) rather than N * T
    for eager copies: each generation appends one node per particle and
    clones via deep_copy.
    """
    import random

    random.seed(0)
    N, T = 8, 30
    live = {}
    for mode in (CopyMode.EAGER, CopyMode.LAZY_SR):
        rt = Runtime(mode)
        particles = [rt.new(value=0) for _ in range(N)]
        for t in range(1, T):
            # resample: multinomial over uniform weights
            ancestors = [random.randrange(N) for _ in range(N)]
            new = [rt.deep_copy(particles[a]) for a in ancestors]
            for p in particles:
                rt.drop(p)
            particles = new
            # propagate: push a new head node per particle
            heads = []
            for p in particles:
                h = rt.new(value=t)
                rt.write(h, "next", p)
                rt.drop(p)
                heads.append(h)
            particles = heads
        live[mode] = rt.stats.live
    # Eager keeps every copied chain: ~ N * T nodes. Lazy keeps the
    # ancestry tree: well below half of that on random resampling.
    assert live[CopyMode.EAGER] >= N * (T - 1) * 0.9
    assert live[CopyMode.LAZY_SR] < live[CopyMode.EAGER] * 0.6
