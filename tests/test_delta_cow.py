"""Sub-block delta COW + fused clone chain (DESIGN.md §3.2).

The contracts under test:

* ``delta_cow=True`` is **observationally** equivalent to the
  whole-block path: valid-prefix trajectories, point reads, and lengths
  are bit-exact.  Pool internals legitimately diverge (delta parents
  outlive their children, shifting the free-stack order and hence the
  allocated block ids), so tables and payload are *not* compared across
  the switch.
* Within ``delta_cow=True``, ``use_kernels=True`` is **leaf**-exact
  with the jnp fallback — data, parent, dirty, refcount, free stack,
  tables all bitwise equal.
* The fused ``clone_chain`` is ancestor-bit-exact with
  ``resample_systematic`` + ``clone`` and produces a leaf-identical
  store, across every CopyMode, NULL table entries, and a 1-shard
  sharded trace (which composes).
* ``kv_cache.ensure_writable`` keeps its invariants when a write's
  dirty slice straddles the last valid row and the dump row (masked
  rows, degeneration at the block boundary).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import pool as pool_lib
from repro.core import store as store_lib
from repro.core.config import CopyMode
from repro.core.pool import NULL_BLOCK
from repro.core.store import StoreConfig
from repro.serving import kv_cache as kv_lib
from repro.serving.kv_cache import KVCacheConfig
from repro.smc import resampling

KEY = jax.random.PRNGKey(0)
LAZY_MODES = [CopyMode.LAZY, CopyMode.LAZY_SR]
ALL_MODES = [CopyMode.EAGER, CopyMode.LAZY, CopyMode.LAZY_SR]


def _delta_program(cfg: StoreConfig):
    """COW-heavy program: clones force sharing, mid-block writes force
    sub-block copies, masked writes leave rows untouched."""
    s = store_lib.create(cfg)
    rows = jnp.arange(cfg.n, dtype=jnp.float32)
    for t in range(4):
        s = store_lib.append(cfg, s, rows * 10 + t)
    # Mid-block clone: every survivor's tail block is shared mid-page.
    s = store_lib.clone(cfg, s, jnp.zeros((cfg.n,), jnp.int32))
    s = store_lib.append(cfg, s, rows + 100)  # divergence -> delta COW
    s = store_lib.write_at(
        cfg,
        s,
        jnp.full((cfg.n,), 1, jnp.int32),
        -rows,
        mask=jnp.asarray([i % 2 == 0 for i in range(cfg.n)]),
    )
    # Fill the tail block: the delta pages degenerate back to full.
    for t in range(cfg.block_size):
        s = store_lib.append(cfg, s, rows + 200 + t)
    s = store_lib.clone(
        cfg, s, jnp.asarray((np.arange(cfg.n) // 2).astype(np.int32))
    )
    return s


def _valid_prefix(cfg: StoreConfig, s) -> np.ndarray:
    """Batch trajectories with positions past each length zeroed."""
    mats = store_lib.materialize_batch(cfg, s, jnp.arange(cfg.n, dtype=jnp.int32))
    valid = np.arange(cfg.capacity)[None, :] < np.asarray(s.lengths)[:, None]
    out = np.asarray(mats).copy()
    out[~valid] = 0
    return out


class TestDeltaStoreObservational:
    @pytest.mark.parametrize("mode", LAZY_MODES)
    @pytest.mark.parametrize("use_kernels", [False, True])
    def test_delta_on_off_equivalent(self, mode, use_kernels):
        base = dict(
            mode=mode, n=4, block_size=3, max_blocks=6, num_blocks=40,
            use_kernels=use_kernels,
        )
        s_off = _delta_program(StoreConfig(**base))
        s_on = _delta_program(StoreConfig(**base, delta_cow=True))
        np.testing.assert_array_equal(
            np.asarray(s_off.lengths), np.asarray(s_on.lengths)
        )
        cfg_off = StoreConfig(**base)
        cfg_on = StoreConfig(**base, delta_cow=True)
        np.testing.assert_array_equal(
            _valid_prefix(cfg_off, s_off), _valid_prefix(cfg_on, s_on)
        )
        # Point reads resolve through parent pages identically.
        for t in (0, 2, 4, 5):
            idx = jnp.full((4,), t, jnp.int32)
            np.testing.assert_array_equal(
                np.asarray(store_lib.read_at(cfg_off, s_off, idx)),
                np.asarray(store_lib.read_at(cfg_on, s_on, idx)),
            )
        # Pool invariants hold with parents in play.
        assert bool(pool_lib.free_stack_consistent(s_on.pool))
        assert bool(pool_lib.refcount_matches_tables(s_on.pool, s_on.tables))

    @pytest.mark.parametrize("mode", LAZY_MODES)
    def test_delta_pages_actually_created(self, mode):
        """The program must exercise the delta path, not degenerate to
        whole-block copies (otherwise the parity above is vacuous)."""
        cfg = StoreConfig(
            mode=mode, n=4, block_size=3, max_blocks=6, num_blocks=40,
            delta_cow=True,
        )
        s = store_lib.create(cfg)
        rows = jnp.arange(4, dtype=jnp.float32)
        for t in range(4):
            s = store_lib.append(cfg, s, rows + t)
        s = store_lib.clone(cfg, s, jnp.zeros((4,), jnp.int32))
        s = store_lib.append(cfg, s, rows + 100)
        assert int((np.asarray(s.pool.parent) >= 0).sum()) > 0
        assert bool(np.asarray(s.pool.dirty).any())

    @pytest.mark.parametrize("mode", LAZY_MODES)
    def test_kernel_leaf_exact_under_delta(self, mode):
        """use_kernels flips the implementation, not the state: every
        pool leaf (including parent/dirty) is bitwise identical."""
        base = dict(
            mode=mode, n=4, block_size=3, max_blocks=6, num_blocks=40,
            delta_cow=True,
        )
        sj = _delta_program(StoreConfig(**base, use_kernels=False))
        sk = _delta_program(StoreConfig(**base, use_kernels=True))
        np.testing.assert_array_equal(np.asarray(sj.tables), np.asarray(sk.tables))
        np.testing.assert_array_equal(np.asarray(sj.lengths), np.asarray(sk.lengths))
        for leaf in ("data", "refcount", "frozen", "free_stack", "parent", "dirty"):
            np.testing.assert_array_equal(
                np.asarray(getattr(sj.pool, leaf)),
                np.asarray(getattr(sk.pool, leaf)),
                err_msg=leaf,
            )
        assert int(sj.pool.free_top) == int(sk.pool.free_top)

    def test_degeneration_clears_bookkeeping(self):
        """Filling a delta page's mask degenerates it to a full block:
        parent cleared, mask cleared, the parent reference released."""
        cfg = StoreConfig(
            mode=CopyMode.LAZY_SR, n=2, block_size=3, max_blocks=4,
            num_blocks=20, delta_cow=True,
        )
        s = store_lib.create(cfg)
        rows = jnp.arange(2, dtype=jnp.float32)
        s = store_lib.append(cfg, s, rows)  # pos 0 of block 0
        s = store_lib.clone(cfg, s, jnp.zeros((2,), jnp.int32))  # share
        for t in range(1, 3):  # pos 1: COW-delta; pos 2: in-place mark
            s = store_lib.append(cfg, s, rows + t)
        # The pre-share slot still resolves through the parent...
        assert (np.asarray(s.pool.parent) >= 0).any()
        # ...until a history rewrite fills the mask: the pages turn into
        # full blocks and the now-unreferenced parent is reclaimed.
        s = store_lib.write_at(cfg, s, jnp.zeros((2,), jnp.int32), rows + 50)
        assert not (np.asarray(s.pool.parent) >= 0).any()
        assert not np.asarray(s.pool.dirty).any()
        assert bool(pool_lib.free_stack_consistent(s.pool))
        assert bool(pool_lib.refcount_matches_tables(s.pool, s.tables))


def _effective_kv(cache, delta: bool) -> np.ndarray:
    """Per-sequence effective payload: ``[S, mb, L, 2, bs, KVH, hd]``
    with NULL blocks zeroed — delta pages resolved through parent."""
    pool = cache.pool
    tab = np.asarray(cache.tables)
    safe = np.maximum(tab, 0)
    data = np.asarray(pool.data)[safe]
    if delta:
        par = np.asarray(pool.parent)[safe]
        res = np.where(par >= 0, par, safe)
        sel = np.asarray(pool.dirty)[safe][:, :, None, None, :, None, None]
        data = np.where(sel, data, np.asarray(pool.data)[res])
    data[tab < 0] = 0
    # Zero positions at or past each sequence's length.
    s, mb = tab.shape
    bs = data.shape[4]
    pos = (np.arange(mb * bs).reshape(mb, bs))[None]  # [1, mb, bs]
    ok = pos < np.asarray(cache.lengths)[:, None, None]
    data = np.where(ok[:, :, None, None, :, None, None], data, 0)
    return data


def _kv_program(cfg: KVCacheConfig, steps: int = 5):
    """Token-by-token KV writes with a mid-block fork and masked rows."""
    cache = kv_lib.create(cfg)
    S = cfg.max_seqs
    k = jax.random.normal(KEY, (steps, cfg.n_layers, S, cfg.n_kv_heads, cfg.head_dim))
    for t in range(steps):
        if t == 2:  # mid-block fork: tails become shared mid-page
            cache = kv_lib.fork(cache, jnp.zeros((S,), jnp.int32))
        mask = jnp.asarray([True] * (S - 1) + [t % 2 == 0])
        cache, bid, pos = kv_lib.ensure_writable(cfg, cache, mask)
        for layer in range(cfg.n_layers):
            cache = kv_lib.write_kv(
                cfg, cache, bid, pos, layer, k[t, layer], -k[t, layer], mask
            )
        cache = kv_lib.advance(cache, mask)
    return cache


class TestKVCacheDelta:
    def _cfg(self, **kw):
        base = dict(
            n_layers=2, n_kv_heads=1, head_dim=4, block_size=4, max_seqs=3,
            max_blocks_per_seq=4, num_blocks=16,
        )
        base.update(kw)
        return KVCacheConfig(**base)

    def test_observational_parity_with_whole_block(self):
        c_off = self._cfg()
        c_on = self._cfg(delta_cow=True)
        cache_off = _kv_program(c_off)
        cache_on = _kv_program(c_on)
        np.testing.assert_array_equal(
            np.asarray(cache_off.lengths), np.asarray(cache_on.lengths)
        )
        np.testing.assert_array_equal(
            _effective_kv(cache_off, delta=False),
            _effective_kv(cache_on, delta=True),
        )
        assert int((np.asarray(cache_on.pool.parent) >= 0).sum()) > 0
        assert bool(pool_lib.free_stack_consistent(cache_on.pool))
        assert bool(pool_lib.refcount_matches_tables(cache_on.pool, cache_on.tables))

    def test_boundary_straddle_and_dump_row(self):
        """Regression: a step whose dirty slice straddles the last valid
        row and the dump row — masked rows park their delta bookkeeping
        scatter on the dump index (dropped), and the write that fills
        the page at the block boundary degenerates it cleanly."""
        cfg = self._cfg(delta_cow=True, block_size=3)
        cache = kv_lib.create(cfg)
        S = 3
        # Two tokens, fork at pos 2 -> shared mid-block tails.
        for t in range(2):
            mask = jnp.asarray([True, True, True])
            cache, bid, pos = kv_lib.ensure_writable(cfg, cache, mask)
            payload = jnp.full((S, 1, 4), float(t + 1))
            for layer in range(2):
                cache = kv_lib.write_kv(
                    cfg, cache, bid, pos, layer, payload, -payload, mask
                )
            cache = kv_lib.advance(cache, mask)
        cache = kv_lib.fork(cache, jnp.asarray([0, 0, 1], jnp.int32))
        # The straddling step: rows 0/1 delta-COW the shared tail (their
        # write lands at pos 2 — the page's last row), row 2 is masked
        # (its scatters must land on the dump row and be dropped).
        mask = jnp.asarray([True, True, False])
        cache, bid, pos = kv_lib.ensure_writable(cfg, cache, mask)
        payload = jnp.full((S, 1, 4), 9.0)
        for layer in range(2):
            cache = kv_lib.write_kv(
                cfg, cache, bid, pos, layer, payload, -payload, mask
            )
        cache = kv_lib.advance(cache, mask)
        pool = cache.pool
        nb = pool.num_blocks
        # Dump row stayed kept-zero, and its bookkeeping was dropped,
        # not written (the dirty/parent scatters have no row nb).
        assert not np.asarray(pool.data[nb]).any()
        # Rows 0/1 hold a delta page: only the boundary row is local,
        # slots 0..1 resolve through the still-live parent (KV appends
        # never rewrite history, so the page never degenerates).
        rows = np.arange(3)
        idx = np.asarray(cache.lengths) // 3
        tails = np.asarray(cache.tables)[rows, np.maximum(idx - 1, 0)]
        for s_i in (0, 1):
            b = tails[s_i]
            assert int(np.asarray(pool.parent)[b]) >= 0
            np.testing.assert_array_equal(
                np.asarray(pool.dirty)[b], np.asarray([False, False, True])
            )
        assert bool(pool_lib.free_stack_consistent(pool))
        assert bool(pool_lib.refcount_matches_tables(pool, cache.tables))
        # And the payload is what the whole-block path would hold:
        # tokens 1, 2 from the shared prefix, 9 at the boundary row.
        eff = _effective_kv(cache, delta=True)
        got = eff[0, 0, 0, 0, :3, 0, 0]  # seq 0, block 0, layer 0, K
        np.testing.assert_array_equal(got, np.asarray([1.0, 2.0, 9.0]))

    def test_free_cascade_reclaims_everything(self):
        cfg = self._cfg(delta_cow=True)
        cache = _kv_program(cfg)
        cache = kv_lib.free(cache, jnp.asarray([True] * 3))
        assert int(pool_lib.blocks_in_use(cache.pool)) == 0
        assert not (np.asarray(cache.pool.parent) >= 0).any()
        assert not np.asarray(cache.pool.dirty).any()
        assert bool(pool_lib.free_stack_consistent(cache.pool))


class TestCloneChainParity:
    @pytest.mark.parametrize("use_kernel", [False, True])
    def test_op_matches_composed(self, use_kernel):
        """Fused op vs resample_systematic + gather + histogram, with
        NULL entries in the tables."""
        from repro.kernels.clone_chain import clone_chain
        from repro.kernels.refcount_update.ref import refcount_delta_ref

        for n, mb, nb, seed in [(8, 4, 30, 0), (33, 5, 40, 1), (256, 3, 64, 2)]:
            key = jax.random.PRNGKey(seed)
            logw = jax.random.normal(jax.random.PRNGKey(seed + 50), (n,))
            tables = jax.random.randint(
                jax.random.PRNGKey(seed + 99), (n, mb), -1, nb
            ).astype(jnp.int32)
            anc0 = resampling.resample_systematic(key, logw)
            new0 = tables[anc0]
            d0, m0 = refcount_delta_ref(new0.reshape(-1), tables.reshape(-1), nb)
            anc, new, d, m = clone_chain(
                key, logw, tables, num_blocks=nb,
                use_kernel=use_kernel, interpret=use_kernel,
            )
            np.testing.assert_array_equal(np.asarray(anc), np.asarray(anc0))
            np.testing.assert_array_equal(np.asarray(new), np.asarray(new0))
            np.testing.assert_array_equal(np.asarray(d), np.asarray(d0))
            np.testing.assert_array_equal(np.asarray(m), np.asarray(m0))

    @pytest.mark.parametrize("mode", ALL_MODES)
    @pytest.mark.parametrize("use_kernels", [False, True])
    @pytest.mark.parametrize("delta_cow", [False, True])
    def test_store_matches_composed(self, mode, use_kernels, delta_cow):
        if mode is CopyMode.EAGER and (use_kernels or delta_cow):
            pytest.skip("EAGER has no pool/kernels")
        cfg = StoreConfig(
            mode=mode, n=6, block_size=3, max_blocks=4, num_blocks=40,
            use_kernels=use_kernels, delta_cow=delta_cow,
        )
        s = store_lib.create(cfg)
        rows = jnp.arange(6, dtype=jnp.float32)
        for t in range(7):  # trailing table entries stay NULL
            s = store_lib.append(cfg, s, rows + t)
        logw = jax.random.normal(jax.random.PRNGKey(7), (6,))
        k = jax.random.PRNGKey(42)
        s0 = store_lib.clone(cfg, s, resampling.resample_systematic(k, logw))
        s1, anc = store_lib.clone_chain(cfg, s, k, logw)
        np.testing.assert_array_equal(
            np.asarray(anc),
            np.asarray(resampling.resample_systematic(k, logw)),
        )
        np.testing.assert_array_equal(np.asarray(s0.lengths), np.asarray(s1.lengths))
        if mode is CopyMode.EAGER:
            np.testing.assert_array_equal(np.asarray(s0.dense), np.asarray(s1.dense))
            return
        np.testing.assert_array_equal(np.asarray(s0.tables), np.asarray(s1.tables))
        for leaf in ("data", "refcount", "frozen", "free_stack", "parent", "dirty"):
            np.testing.assert_array_equal(
                np.asarray(getattr(s0.pool, leaf)),
                np.asarray(getattr(s1.pool, leaf)),
                err_msg=leaf,
            )
        assert int(s0.pool.free_top) == int(s1.pool.free_top)

    def test_sharded_1shard_trace_composes(self):
        """A 1-shard sharded token trace routes clone_chain through the
        composed sharded clone with the identical ancestors."""
        from repro.serving.smc_decode import _TokenTrace

        mesh = Mesh(np.array(jax.devices()[:1]), ("shards",))
        steps = 6
        tr_sh = _TokenTrace(4, steps, CopyMode.LAZY_SR, 3, mesh, "shards")
        tr_1d = _TokenTrace(4, steps, CopyMode.LAZY_SR, 3, None, "shards")
        for t in range(4):
            tok = jnp.arange(4, dtype=jnp.int32) + 10 * t
            tr_sh.append(tok)
            tr_1d.append(tok)
        logw = jax.random.normal(jax.random.PRNGKey(3), (4,))
        k = jax.random.PRNGKey(11)
        anc_sh = tr_sh.clone_chain(k, logw)
        anc_1d = tr_1d.clone_chain(k, logw)
        np.testing.assert_array_equal(np.asarray(anc_sh), np.asarray(anc_1d))
        np.testing.assert_array_equal(
            np.asarray(tr_sh.tokens(4)), np.asarray(tr_1d.tokens(4))
        )

    def test_scheduler_fork_unchanged_by_fusion(self):
        """The fused fork path must leave the scheduled decode
        token-bit-exact: smc_token_update's ancestors and the trace's
        clone_chain ancestors are drawn from the same key."""
        from repro.serving.smc_decode import smc_token_update

        key = jax.random.PRNGKey(5)
        logits = jax.random.normal(jax.random.PRNGKey(6), (4, 11))
        logw = jnp.full((4,), -np.log(4.0))
        out = smc_token_update(
            key, logits, logw, jnp.zeros(()), n=4,
            target_temp=0.3, proposal_temp=1.0, ess_threshold=1.1,
        )
        _, _, new_logw, _, _, do_res, anc, k_res = out
        assert do_res and anc is not None
        np.testing.assert_array_equal(
            np.asarray(anc),
            np.asarray(resampling.resample_systematic(k_res, new_logw)),
        )
