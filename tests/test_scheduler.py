"""Continuous-batching scheduler tests (DESIGN.md §8).

The contract under test:

  * two concurrent requests over one shared pool produce tokens
    **bit-identical** to two sequential single-request decoder runs
    (per-row independence of the one jitted decode step);
  * preempt-then-resume is bit-exact with an uninterrupted run (pages
    are freed, the token history + replay log re-derive every KV page);
  * admission refused on a full pool *surfaces* (no silent drop):
    loudly via :class:`AdmissionRefused` when no progress is possible,
    by waiting when departures will free capacity;
  * pool pressure grows first (§3.1 policy) and preempts second, and
    both paths keep results bit-exact;
  * shared-pool peak stays below the sum of the requests'
    dense-equivalent caches (the paper's population-sharing claim,
    multiplied across requests).
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.model import LanguageModel
from repro.serving.engine import ServeEngine
from repro.serving.kv_cache import KVCacheConfig
from repro.serving.scheduler import (
    AdmissionRefused,
    DecodeRequest,
    Scheduler,
    SlotTable,
)
from repro.serving.smc_decode import SMCDecoder

KEY = jax.random.PRNGKey(0)
BS = 4  # page/block size used throughout


@pytest.fixture(scope="module")
def model():
    cfg = smoke_config("musicgen_large")
    lm = LanguageModel(cfg)
    params, _ = lm.init(KEY)
    return cfg, lm, params


def make_engine(model, max_seqs, num_blocks=0, max_blocks_per_seq=24):
    cfg, lm, params = model
    ccfg = KVCacheConfig(
        n_layers=cfg.n_layers,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd,
        block_size=BS,
        max_seqs=max_seqs,
        max_blocks_per_seq=max_blocks_per_seq,
        num_blocks=num_blocks,
        dtype=cfg.dtype,
    )
    return ServeEngine(lm, params, ccfg)


def make_request(model, rid, seed, n, steps, plen):
    cfg, _, _ = model
    return DecodeRequest(
        rid=rid,
        prompt=jax.random.randint(
            jax.random.PRNGKey(seed),
            (plen,),
            0,
            cfg.vocab_size,
        ),
        n_particles=n,
        steps=steps,
        key=jax.random.PRNGKey(100 + seed),
        target_temp=0.5,
        token_block_size=BS,
    )


def reference_run(model, req: DecodeRequest):
    """The request decoded standalone by a private SMCDecoder."""
    _, lm, params = model
    dec = SMCDecoder(
        lm,
        params,
        n_particles=req.n_particles,
        max_len=96,
        target_temp=req.target_temp,
        proposal_temp=req.proposal_temp,
        block_size=BS,
    )
    return dec.run(req.key, req.prompt, req.steps)


class TestSlotTable:
    def test_pack_free_refill(self):
        t = SlotTable(10)
        a, b, c = t.alloc(4), t.alloc(3), t.alloc(3)
        assert (a, b, c) == (0, 4, 7) and t.free_slots == 0
        assert t.alloc(1) is None
        t.free(4, 3)  # free the middle range
        assert t.alloc(4) is None  # no contiguous 4
        assert t.alloc(2) == 4  # first-fit into the gap
        t.free(0, 4)
        assert t.alloc(4) == 0

    def test_double_free_raises(self):
        t = SlotTable(10)
        t.alloc(4)
        t.free(0, 4)
        with pytest.raises(ValueError, match="double free"):
            t.free(0, 4)

    def test_overlapping_free_raises(self):
        t = SlotTable(10)
        t.alloc(4)
        with pytest.raises(ValueError, match="no such allocated range"):
            t.free(0, 2)  # partial range
        with pytest.raises(ValueError, match="no such allocated range"):
            t.free(2, 4)  # straddles the allocation
        t.free(0, 4)  # the exact range is still fine


class TestConcurrency:
    def test_two_concurrent_bit_exact_with_sequential(self, model):
        """The acceptance gate: a two-request scheduler run is
        token-bit-exact with two sequential single-request runs, and
        the shared pool's peak stays under the sum of the requests'
        dense-equivalent caches."""
        ra = make_request(model, "a", 1, n=8, steps=10, plen=6)
        rb = make_request(model, "b", 2, n=6, steps=13, plen=9)
        ref = {r.rid: reference_run(model, r) for r in (ra, rb)}

        eng = make_engine(model, max_seqs=ra.n_particles + rb.n_particles)
        sched = Scheduler(eng)
        sched.submit(ra)
        sched.submit(rb)
        res = sched.run()
        for r in (ra, rb):
            np.testing.assert_array_equal(
                np.asarray(res[r.rid].tokens), np.asarray(ref[r.rid].tokens)
            )
            np.testing.assert_array_equal(
                np.asarray(res[r.rid].log_weights),
                np.asarray(ref[r.rid].log_weights),
            )
            assert float(res[r.rid].log_evidence) == float(ref[r.rid].log_evidence)
            np.testing.assert_array_equal(
                np.asarray(res[r.rid].resampled),
                np.asarray(ref[r.rid].resampled),
            )
            assert not bool(res[r.rid].oom)
        # shared-pool peak < sum of dense-equivalent per-request caches
        peak = max(
            int(np.max(np.asarray(res[r.rid].used_blocks_trace)))
            for r in (ra, rb)
        )
        dense = sum(
            r.n_particles * -(-(len(r.prompt) + r.steps) // BS)
            for r in (ra, rb)
        )
        assert peak < dense, (peak, dense)
        assert sched.stats.completed == 2 and sched.stats.preemptions == 0

    def test_queue_overflow_waits_no_silent_drop(self, model):
        """Three requests over a slot table that fits one at a time:
        admission waits for departures, and every request completes
        bit-exactly (no silent drop, FIFO order)."""
        reqs = [
            make_request(model, f"r{i}", 10 + i, n=4, steps=6, plen=4)
            for i in range(3)
        ]
        ref = {r.rid: reference_run(model, r) for r in reqs}
        eng = make_engine(model, max_seqs=4)
        sched = Scheduler(eng)
        for r in reqs:
            sched.submit(r)
        res = sched.run()
        assert set(res) == {"r0", "r1", "r2"}
        for r in reqs:
            np.testing.assert_array_equal(
                np.asarray(res[r.rid].tokens), np.asarray(ref[r.rid].tokens)
            )
        assert sched.stats.admitted == 3 and sched.stats.completed == 3

    def test_staggered_arrival_bit_exact(self, model):
        """A request arriving mid-flight (continuous batching: it joins
        the running batch at a token boundary) decodes the same tokens
        as a standalone run."""
        ra = make_request(model, "a", 5, n=6, steps=12, plen=4)
        rb_base = make_request(model, "b", 6, n=4, steps=8, plen=6)
        import dataclasses

        rb = dataclasses.replace(rb_base, arrive_at=5)
        ref = {r.rid: reference_run(model, r) for r in (ra, rb)}
        eng = make_engine(model, max_seqs=10)
        sched = Scheduler(eng)
        sched.submit(ra)
        sched.submit(rb)
        res = sched.run()
        for r in (ra, rb):
            np.testing.assert_array_equal(
                np.asarray(res[r.rid].tokens), np.asarray(ref[r.rid].tokens)
            )


class TestAdmission:
    def test_refused_on_full_pool_surfaces(self, model):
        """A request whose worst-case demand exceeds a fixed full pool
        raises AdmissionRefused — no silent drop, no garbage result."""
        req = make_request(model, "big", 3, n=8, steps=8, plen=8)
        # demand = ceil(8/4) + 8 = 10 pages > 6-block fixed pool
        eng = make_engine(model, max_seqs=8, num_blocks=6)
        sched = Scheduler(eng, grow=False)
        sched.submit(req)
        with pytest.raises(AdmissionRefused, match="big"):
            sched.run()
        assert sched.stats.completed == 0

    def test_sticky_pool_oom_does_not_taint_later_requests(self, model):
        """The shared pool's oom flag is sticky; a request admitted
        AFTER the flag was set (and decoding within freed capacity)
        must not inherit the earlier request's failure."""
        bad = make_request(model, "bad", 8, n=8, steps=10, plen=4)
        eng = make_engine(model, max_seqs=8, num_blocks=12)
        sched = Scheduler(eng, grow=False, strict_admission=False)
        sched.submit(bad)
        res = sched.run()
        assert bool(res["bad"].oom)  # genuinely exhausted
        small = make_request(model, "small", 9, n=2, steps=4, plen=4)
        ref = reference_run(model, small)
        sched2 = Scheduler(eng, grow=False, strict_admission=False)
        sched2.submit(small)
        res2 = sched2.run()
        assert not bool(res2["small"].oom)  # clean run, clean flag
        np.testing.assert_array_equal(
            np.asarray(res2["small"].tokens), np.asarray(ref.tokens)
        )

    def test_duplicate_rid_rejected_even_after_completion(self, model):
        req = make_request(model, "a", 1, n=4, steps=2, plen=4)
        eng = make_engine(model, max_seqs=4)
        sched = Scheduler(eng)
        sched.submit(req)
        sched.run()
        with pytest.raises(ValueError, match="duplicate"):
            sched.submit(make_request(model, "a", 2, n=4, steps=2, plen=4))

    def test_refused_on_full_slot_table_surfaces(self, model):
        req = make_request(model, "wide", 4, n=8, steps=4, plen=4)
        eng = make_engine(model, max_seqs=4)  # 8 particles, 4 slots
        sched = Scheduler(eng)
        sched.submit(req)
        with pytest.raises(AdmissionRefused, match="slots"):
            sched.run()


class TestPreemption:
    def test_forced_preempt_resume_bit_exact(self, model):
        """Force a preemption mid-flight: pages freed, token history
        retained, resume replays — final results bit-exact with an
        uninterrupted run."""
        req = make_request(model, "a", 7, n=8, steps=12, plen=6)
        ref = reference_run(model, req)

        fired = []

        def force_once(sched):
            active = list(sched._active)
            if active and active[0].t_done == 5 and not fired:
                fired.append(True)
                sched.preempt("a")

        eng = make_engine(model, max_seqs=8)
        sched = Scheduler(eng, on_boundary=force_once)
        sched.submit(req)
        res = sched.run()["a"]
        assert res.preemptions == 1 and sched.stats.replayed_tokens == 5
        np.testing.assert_array_equal(np.asarray(res.tokens), np.asarray(ref.tokens))
        np.testing.assert_array_equal(
            np.asarray(res.log_weights), np.asarray(ref.log_weights)
        )
        assert float(res.log_evidence) == float(ref.log_evidence)
        np.testing.assert_array_equal(
            np.asarray(res.ess_trace), np.asarray(ref.ess_trace)
        )
        assert not bool(res.oom)

    def test_pressure_preemption_recovers_bit_exact(self, model):
        """A fixed pool too small for two full populations: the
        scheduler preempts (newest first) instead of corrupting, the
        preempted request resumes after the incumbent departs, and both
        finish bit-exactly."""
        ra = make_request(model, "a", 1, n=4, steps=16, plen=4)
        rb = make_request(model, "b", 2, n=4, steps=16, plen=4)
        ref = {r.rid: reference_run(model, r) for r in (ra, rb)}
        eng = make_engine(model, max_seqs=8, num_blocks=20)
        sched = Scheduler(eng, grow=False)
        sched.submit(ra)
        sched.submit(rb)
        res = sched.run()
        assert sched.stats.preemptions >= 1
        for r in (ra, rb):
            np.testing.assert_array_equal(
                np.asarray(res[r.rid].tokens), np.asarray(ref[r.rid].tokens)
            )
            assert not bool(res[r.rid].oom)

    def test_growth_preferred_over_preemption(self, model):
        """With growth on (the §3.1 policy), the same pressure scenario
        grows the shared pool and never preempts."""
        ra = make_request(model, "a", 1, n=4, steps=16, plen=4)
        rb = make_request(model, "b", 2, n=4, steps=16, plen=4)
        ref = {r.rid: reference_run(model, r) for r in (ra, rb)}
        eng = make_engine(model, max_seqs=8, num_blocks=8)
        sched = Scheduler(eng)
        sched.submit(ra)
        sched.submit(rb)
        res = sched.run()
        assert sched.stats.preemptions == 0
        assert eng.num_blocks > 8  # the pool grew instead
        for r in (ra, rb):
            np.testing.assert_array_equal(
                np.asarray(res[r.rid].tokens), np.asarray(ref[r.rid].tokens)
            )

    def test_shrink_on_complete_is_invisible(self, model):
        """Compaction when the batch thins out returns memory without
        touching results (observational invisibility, §3.1)."""
        ra = make_request(model, "a", 1, n=6, steps=6, plen=4)
        rb = make_request(model, "b", 2, n=4, steps=14, plen=4)
        ref = {r.rid: reference_run(model, r) for r in (ra, rb)}
        eng = make_engine(model, max_seqs=10)
        sched = Scheduler(eng, shrink_on_complete=True)
        sched.submit(ra)
        sched.submit(rb)
        res = sched.run()
        assert sched.stats.compactions >= 1
        for r in (ra, rb):
            np.testing.assert_array_equal(
                np.asarray(res[r.rid].tokens), np.asarray(ref[r.rid].tokens)
            )
            assert not bool(res[r.rid].oom)
