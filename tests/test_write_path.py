"""Kernelized write path: interpret-mode parity + roofline acceptance.

``cow_write`` and ``refcount_update`` must be bit-exact with their
``ref.py`` oracles, with each other across the ``StoreConfig.use_kernels``
switch (jnp fused path vs interpret-mode Pallas path) for all three
CopyModes — including ``write_at`` with partial masks and NULL table
entries — and with the pre-kernelization six-pass jnp path (reconstructed
in ``benchmarks/bench_write_path.py``).  Pool content is compared on the
``num_blocks`` live rows; the dump row is kept zero by contract.

The roofline gate (the PR's acceptance criterion) asserts the byte/pass
reduction through :mod:`repro.roofline.write_path` — host-independent,
so it runs on CPU CI where interpret-mode wall-clock would be
meaningless.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pool as pool_lib
from repro.core import store as store_lib
from repro.core.config import ALL_MODES, CopyMode
from repro.core.store import StoreConfig
from repro.kernels.cow_write.ops import cow_write
from repro.kernels.cow_write.ref import cow_write_ref
from repro.kernels.refcount_update.ops import refcount_update
from repro.kernels.refcount_update.ref import refcount_delta_ref
from repro.roofline.write_path import append_cost, chain_cost, clone_cost

KEY = jax.random.PRNGKey(0)


class TestCowWriteKernel:
    @pytest.mark.parametrize(
        "nb,bs,item", [(8, 4, ()), (16, 2, (3,)), (8, 8, (2, 2))]
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
    def test_parity_with_ref(self, nb, bs, item, dtype):
        n = 6
        if dtype == jnp.int32:
            data = jax.random.randint(KEY, (nb + 1, bs, *item), 0, 100, dtype)
            values = jax.random.randint(KEY, (n, *item), 0, 100, dtype)
        else:
            data = jax.random.normal(KEY, (nb + 1, bs, *item), dtype)
            values = jax.random.normal(jax.random.PRNGKey(1), (n, *item), dtype)
        data = data.at[nb].set(0)
        # rows: COW (0->5), in-place (1->1), fresh (6->6), dump-skips
        src = jnp.array([0, 1, 6, nb, nb, 2], jnp.int32)
        dst = jnp.array([5, 1, 6, nb, nb, 7], jnp.int32)
        pos = jnp.array([2, 0, bs - 1, 0, 1, 1], jnp.int32)
        out_k = cow_write(data, src, dst, pos, values, use_kernel=True)
        out_r = cow_write(data, src, dst, pos, values, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
        # dump row stays zero on both paths
        assert not np.asarray(out_k[nb]).any()
        # untouched rows bitwise-preserved
        untouched = sorted(set(range(nb)) - set(np.asarray(dst).tolist()))
        np.testing.assert_array_equal(
            np.asarray(out_k)[untouched], np.asarray(data)[untouched]
        )

    def test_ref_matches_manual_semantics(self):
        data = jnp.arange(3 * 4, dtype=jnp.float32).reshape(3, 4)  # nb=2 + dump
        out = cow_write_ref(
            data,
            jnp.array([0], jnp.int32),
            jnp.array([1], jnp.int32),
            jnp.array([2], jnp.int32),
            jnp.array([9.0]),
        )
        np.testing.assert_allclose(np.asarray(out[1]), [0.0, 1.0, 9.0, 3.0])
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(data[0]))


class TestRefcountUpdateKernel:
    @pytest.mark.parametrize("nb,e", [(8, 12), (40, 64), (16, 300)])
    def test_parity_with_ref(self, nb, e):
        rng = np.random.default_rng(nb + e)
        new = jnp.asarray(rng.integers(-1, nb, e).astype(np.int32))
        old = jnp.asarray(rng.integers(-1, nb, e).astype(np.int32))
        refcount = jnp.asarray(rng.integers(0, 4, nb).astype(np.int32))
        frozen = jnp.asarray(rng.integers(0, 2, nb).astype(bool))
        for do_freeze in (False, True):
            rk = refcount_update(
                refcount, frozen, new, old, do_freeze=do_freeze, use_kernel=True
            )
            rr = refcount_update(
                refcount, frozen, new, old, do_freeze=do_freeze, use_kernel=False
            )
            for a, b in zip(rk, rr, strict=True):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_matches_legacy_triple(self):
        """delta == add_refs(new) then sub_refs(old); member == freeze set."""
        nb = 10
        rng = np.random.default_rng(0)
        new = jnp.asarray(rng.integers(-1, nb, 20).astype(np.int32))
        old = jnp.asarray(rng.integers(-1, nb, 20).astype(np.int32))
        delta, member = refcount_delta_ref(new, old, nb)
        expect = np.zeros(nb, np.int32)
        memb = np.zeros(nb, bool)
        for b in np.asarray(new):
            if b >= 0:
                expect[b] += 1
                memb[b] = True
        for b in np.asarray(old):
            if b >= 0:
                expect[b] -= 1
        np.testing.assert_array_equal(np.asarray(delta), expect)
        np.testing.assert_array_equal(np.asarray(member), memb)


def _run_program(cfg: StoreConfig):
    """A program exercising COW, partial-mask write_at, NULL entries,
    clone-induced frees, and batch materialization."""
    s = store_lib.create(cfg)
    rows = jnp.arange(cfg.n, dtype=jnp.float32)
    for t in range(5):  # short: trailing table entries stay NULL
        s = store_lib.append(cfg, s, rows * 10 + t)
    s = store_lib.clone(cfg, s, jnp.zeros((cfg.n,), jnp.int32))
    s = store_lib.append(cfg, s, rows + 100)  # divergence -> COW
    s = store_lib.write_at(
        cfg,
        s,
        jnp.full((cfg.n,), 1, jnp.int32),
        -rows,
        mask=jnp.asarray([i % 2 == 0 for i in range(cfg.n)]),
    )
    s = store_lib.clone(cfg, s, jnp.asarray((np.arange(cfg.n) // 2).astype(np.int32)))
    mats = store_lib.materialize_batch(cfg, s, jnp.arange(cfg.n, dtype=jnp.int32))
    return s, mats


class TestStoreKernelSwitch:
    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_use_kernels_bit_exact(self, mode):
        base = dict(mode=mode, n=4, block_size=3, max_blocks=4, num_blocks=30)
        sj, mj = _run_program(StoreConfig(**base, use_kernels=False))
        sk, mk = _run_program(StoreConfig(**base, use_kernels=True))
        np.testing.assert_array_equal(np.asarray(mj), np.asarray(mk))
        np.testing.assert_array_equal(np.asarray(sj.tables), np.asarray(sk.tables))
        np.testing.assert_array_equal(np.asarray(sj.lengths), np.asarray(sk.lengths))
        if mode is not CopyMode.EAGER:
            nb = sj.pool.num_blocks
            np.testing.assert_array_equal(
                np.asarray(sj.pool.data), np.asarray(sk.pool.data)
            )
            np.testing.assert_array_equal(
                np.asarray(sj.pool.refcount), np.asarray(sk.pool.refcount)
            )
            np.testing.assert_array_equal(
                np.asarray(sj.pool.frozen), np.asarray(sk.pool.frozen)
            )
            np.testing.assert_array_equal(
                np.asarray(sj.pool.free_stack), np.asarray(sk.pool.free_stack)
            )
            assert int(sj.pool.free_top) == int(sk.pool.free_top)
            assert bool(pool_lib.free_stack_consistent(sk.pool))
            assert not np.asarray(sk.pool.data[nb]).any()  # dump row zero

    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_matches_legacy_write_path(self, mode):
        """Observational equivalence with the pre-kernelization six-pass
        path (block ids may differ; trajectories must not)."""
        bench = pytest.importorskip(
            "benchmarks.bench_write_path",
            reason="benchmarks package needs repo-root cwd",
        )
        cfg = StoreConfig(mode=mode, n=4, block_size=3, max_blocks=4, num_blocks=30)
        s_new = store_lib.create(cfg)
        s_old = store_lib.create(cfg)
        rows = jnp.arange(4, dtype=jnp.float32)
        for t in range(6):
            s_new = store_lib.append(cfg, s_new, rows + t)
            if cfg.mode is CopyMode.EAGER:
                s_old = store_lib.append(cfg, s_old, rows + t)
            else:
                s_old = bench.legacy_append(cfg, s_old, rows + t)
            if t == 3:
                anc = jnp.array([0, 0, 1, 2], jnp.int32)
                s_new = store_lib.clone(cfg, s_new, anc)
                s_old = (
                    store_lib.clone(cfg, s_old, anc)
                    if cfg.mode is CopyMode.EAGER
                    else bench.legacy_clone(cfg, s_old, anc)
                )
        for i in range(4):
            np.testing.assert_allclose(
                np.asarray(store_lib.trajectory(cfg, s_new, i))[:6],
                np.asarray(store_lib.trajectory(cfg, s_old, i))[:6],
            )


class TestRooflineAcceptance:
    """The PR's perf acceptance, priced host-independently."""

    def test_append_bytes_and_passes(self):
        cfg = StoreConfig(mode=CopyMode.LAZY_SR, n=1024, block_size=4, max_blocks=16)
        kw = dict(
            n=cfg.n,
            touched=cfg.n,
            copies=cfg.n // 4,
            num_blocks=cfg.pool_blocks,
            block_bytes=4 * cfg.block_size,
            item_bytes=4,
        )
        legacy = append_cost("legacy", **kw)
        fused = append_cost("fused_jnp", **kw)
        kernel = append_cost("kernel", **kw)
        assert legacy.passes >= 2 * kernel.passes
        assert kernel.bytes < fused.bytes < legacy.bytes
        assert kernel.speedup_over(legacy) >= 2.0

    def test_clone_passes(self):
        legacy = clone_cost("legacy", table_entries=1024 * 16, num_blocks=4096)
        kernel = clone_cost("kernel", table_entries=1024 * 16, num_blocks=4096)
        assert legacy.passes == 3 and kernel.passes == 1
        assert kernel.bytes < legacy.bytes

    def test_masked_write_scales_with_touched_rows(self):
        """The kernel only moves touched blocks; the jnp paths move all
        n — the satellite's dense-copy-waste fix, visible in the model."""
        kw = dict(n=1024, copies=0, num_blocks=4096, block_bytes=16, item_bytes=4)
        sparse = append_cost("kernel", touched=32, **kw)
        dense = append_cost("kernel", touched=1024, **kw)
        assert sparse.bytes < dense.bytes
        jnp_sparse = append_cost("fused_jnp", touched=32, **kw)
        assert sparse.bytes < jnp_sparse.bytes

    @pytest.mark.parametrize("bs", [8, 16, 32])
    def test_delta_cow_sparse_write_wins(self, bs):
        """The tentpole gate (DESIGN.md §3.2): a single-element write to
        a freshly shared block moves >= 2x fewer bytes under delta COW
        at block_size >= 8, and grows with the block size."""
        kw = dict(
            n=1024,
            touched=1024,
            copies=1024,
            num_blocks=4096,
            block_bytes=4 * bs,
            item_bytes=4,
        )
        whole = append_cost("kernel", **kw)
        sparse = append_cost("kernel", delta=True, dirty_items=0, **kw)
        assert whole.bytes >= 2 * sparse.bytes, (bs, whole, sparse)

    def test_delta_cow_dense_never_loses(self):
        """A mask-filling write degenerates the page (sheds the
        bookkeeping), so dense delta COW never exceeds whole-block."""
        for bs in (8, 16, 32):
            kw = dict(
                n=1024,
                touched=1024,
                copies=1024,
                num_blocks=4096,
                block_bytes=4 * bs,
                item_bytes=4,
            )
            whole = append_cost("kernel", **kw)
            dense = append_cost("kernel", delta=True, dirty_items=bs - 1, **kw)
            assert dense.bytes <= whole.bytes, (bs, dense, whole)

    def test_chain_fusion_passes_and_bytes(self):
        """Fused resample->gather->refcount: 3 dispatches -> 1 pass and
        >= 1.3x fewer bytes (the tables are read once, the ancestors
        never round-trip through HBM)."""
        kw = dict(n=1024, table_entries=1024 * 16, num_blocks=4096)
        composed = chain_cost("fused_jnp", **kw)
        fused = chain_cost("kernel", **kw)
        assert composed.passes == 3 and fused.passes == 1
        assert composed.bytes >= 1.3 * fused.bytes
        assert chain_cost("legacy", **kw) == composed
