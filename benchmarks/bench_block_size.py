"""Block-granularity sweep — the paper's object-vs-page discussion.

The paper positions object-granular COW between whole-process fork()
(Paige & Wood) and nothing; the array platform's analogue knob is the
block size: small blocks minimize false sharing (COW copies less on
divergence) but cost more table entries; large blocks amortize tables
but copy more per write.  Measured: peak blocks x block bytes for the
motivating PF pattern across block sizes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import CopyMode
from repro.core import store as store_lib
from repro.core.store import StoreConfig

from benchmarks.common import emit


def run(n: int = 128, t: int = 64):
    rows = []
    rng = np.random.default_rng(0)
    ancestors = [rng.integers(0, n, n).astype(np.int32) for _ in range(t)]
    for bs in (1, 2, 4, 8, 16):
        cfg = StoreConfig(
            mode=CopyMode.LAZY_SR, n=n, block_size=bs,
            max_blocks=-(-t // bs), num_blocks=n * (-(-t // bs)),
        )
        s = store_lib.create(cfg)
        append = jax.jit(store_lib.append, static_argnums=0)
        clone = jax.jit(store_lib.clone, static_argnums=0)
        for step in range(t):
            s = append(cfg, s, jnp.zeros((n,)))
            s = clone(cfg, s, jnp.asarray(ancestors[step]))
        peak_items = int(s.peak_blocks) * bs
        table_entries = n * cfg.max_blocks
        rows.append(
            emit(
                "block",
                f"block_size_{bs}",
                0.0,
                f"peak_item_equiv={peak_items};table_entries={table_entries};"
                f"dense={n * t}",
            )
        )
    return rows


if __name__ == "__main__":
    run()
