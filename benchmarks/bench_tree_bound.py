"""Jacob, Murray & Rubenthaler (2015): reachable-set bound.

Measures live blocks of the lazy store across (N, t) under per-step
multinomial resampling against the t + c N log N bound — the theory that
predicts the platform's O(DT + DN log DN) memory.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import CopyMode
from repro.core import store as store_lib
from repro.core.store import StoreConfig

from benchmarks.common import emit


def run(t: int = 100):
    rows = []
    rng = np.random.default_rng(0)
    for n in (32, 128, 512):
        cfg = StoreConfig(
            mode=CopyMode.LAZY_SR, n=n, block_size=1, max_blocks=t, num_blocks=n * t
        )
        s = store_lib.create(cfg)
        worst_ratio = 0.0
        append = jax.jit(store_lib.append, static_argnums=0)
        clone = jax.jit(store_lib.clone, static_argnums=0)
        for step in range(t):
            s = append(cfg, s, jnp.zeros((n,)))
            anc = jnp.asarray(rng.integers(0, n, n), jnp.int32)
            s = clone(cfg, s, anc)
            used = int(store_lib.used_blocks(cfg, s))
            bound = step + 1 + 6 * n * math.log(n)
            worst_ratio = max(worst_ratio, used / bound)
        final = int(store_lib.used_blocks(cfg, s))
        rows.append(
            emit(
                "tree",
                f"tree_bound_N{n}",
                0.0,
                f"final_blocks={final};dense={n * t};"
                f"worst_used/bound={worst_ratio:.3f};bound_c=6",
            )
        )
    return rows


if __name__ == "__main__":
    run()
