"""Paper Figure 6: simulation-task time and memory (no copies occur —
isolates the bookkeeping overhead of lazy pointers)."""

from __future__ import annotations

from repro.core.config import ALL_MODES
from repro.smc.programs import PROBLEMS

from benchmarks.common import build_runner, emit, time_run


def run(n: int = 128, t: int = 48, reps: int = 3):
    rows = []
    for name in PROBLEMS:
        for mode in ALL_MODES:
            runner, cfg = build_runner(name, mode, n, t, simulate=True)
            secs, peak, _ = time_run(runner, reps)
            rows.append(
                emit(
                    "fig6",
                    f"fig6_simulation_{name}_{mode.value}",
                    secs,
                    f"peak_blocks={peak};N={n};T={t}",
                )
            )
    return rows


if __name__ == "__main__":
    run()
