"""Shared benchmark harness utilities."""

from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict, Tuple

import jax
import numpy as np

from repro.core.config import CopyMode
from repro.smc.filters import FilterConfig, ParticleFilter
from repro.smc.pgibbs import ParticleGibbs
from repro.smc.programs import PROBLEMS

KEY = jax.random.PRNGKey(0)

# The reference LGSSM (A x + N(0,Q) transitions, N(x,R) emissions) used
# by benches that need a model lighter than the paper problems.
LGSSM_A, LGSSM_Q, LGSSM_R = 0.9, 0.5, 0.3


def lgssm_def():
    import math

    from repro.smc.filters import SSMDef

    def init(key, n, params):
        return jax.random.normal(key, (n,))

    def step(key, x, t, y_t, params):
        x = LGSSM_A * x + math.sqrt(LGSSM_Q) * jax.random.normal(key, x.shape)
        logw = -0.5 * ((y_t - x) ** 2 / LGSSM_R + math.log(2 * math.pi * LGSSM_R))
        return x, logw, x[:, None]

    def set_reference(state, ref_t):
        # Conditional SMC: push the pinned reference record back into
        # particle 0's state (used by bench_pgibbs and the CSMC tests).
        return state.at[0].set(ref_t[0])

    return SSMDef(
        init=init, step=step, record_shape=(1,), set_reference=set_reference
    )


def build_runner(name: str, mode: CopyMode, n: int, t: int, simulate: bool):
    mod = PROBLEMS[name]
    if mod.NAME == "pcfg":
        ssm, params = mod.build(mode)
    else:
        ssm, params = mod.build()
    obs = mod.gen_data(KEY, t)
    cfg = FilterConfig(
        n_particles=n, n_steps=t, mode=mode,
        max_retries=(6 if mod.METHOD == "alive" else 0),
    )
    if mod.METHOD == "pg" and not simulate:
        pg = ParticleGibbs(ssm, cfg)

        def run(key):
            out = pg.run(key, params, obs, n_iters=3)
            return out.peak_blocks, out.log_evidences[-1]

        return run, cfg
    pf = ParticleFilter(ssm, cfg)
    fn = pf.jitted(simulate=simulate)

    def run(key):
        res = fn(key, params, obs)
        return res.store.peak_blocks, res.log_evidence

    return run, cfg


def time_run(run: Callable, reps: int = 3) -> Tuple[float, int, float]:
    """(median seconds, peak_blocks, logZ) after a warmup call."""
    peak, logz = run(KEY)  # warmup (compile)
    jax.block_until_ready(peak)
    times = []
    for i in range(reps):
        t0 = time.time()
        peak, logz = run(jax.random.PRNGKey(i))
        jax.block_until_ready(peak)
        times.append(time.time() - t0)
    return float(np.median(times)), int(peak), float(logz)


def csv_row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"


# -- machine-readable results ------------------------------------------------
#
# ``run.py --json DIR`` turns every emitted row into an entry of
# ``DIR/BENCH_<suite>.json`` so the perf trajectory is trackable across
# PRs; without it ``emit`` is just the csv print the suites always did.

_json_dir: pathlib.Path | None = None
_json_rows: Dict[str, list] = {}


def enable_json(path: str) -> None:
    global _json_dir
    _json_dir = pathlib.Path(path)
    _json_dir.mkdir(parents=True, exist_ok=True)


def emit(suite: str, name: str, seconds: float, derived: str, **config) -> str:
    """Print one benchmark row (and record it when JSON output is on)."""
    row = csv_row(name, seconds, derived)
    print(row, flush=True)
    if _json_dir is not None:
        _json_rows.setdefault(suite, []).append(
            {
                "name": name,
                "us_per_call": seconds * 1e6,
                "derived": derived,
                "config": config,
            }
        )
    return row


def write_artifact(name: str, obj) -> None:
    """Write a free-form JSON artifact next to the BENCH_*.json files
    (no-op without ``--json``).  Used for telemetry CI uploads but does
    not gate — e.g. the router's per-replica utilization snapshot."""
    if _json_dir is None:
        return
    out = _json_dir / name
    out.write_text(json.dumps(obj, indent=2, sort_keys=True))
    print(f"wrote {out}", flush=True)


def flush_json() -> None:
    """Write one ``BENCH_<suite>.json`` per recorded suite."""
    if _json_dir is None:
        return
    for suite, rows in _json_rows.items():
        out = _json_dir / f"BENCH_{suite}.json"
        out.write_text(json.dumps({"suite": suite, "rows": rows}, indent=2))
        print(f"wrote {out}", flush=True)
    _json_rows.clear()
