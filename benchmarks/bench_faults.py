"""Fault-injection overhead under the recovery layer (DESIGN.md §10).

Measures aggregate decode throughput (tokens/sec) for the same request
schedule at 0% / 5% / 20% injected transient-fault rates (step failures
and forced mid-run OOMs on a seeded :func:`chaos_schedule`), so the
committed baseline remembers both the recovery overhead curve and the
deterministic fault/retry counts.

Gate (the chaos harness's differential contract): every faulted run
must produce tokens, log-weights, and log-evidence **bit-identical** to
the fault-free run — rollback-retry recovery is observationally
invisible, only slower.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import KEY, emit
from repro.configs import smoke_config
from repro.models.model import LanguageModel
from repro.serving import traces as traces_lib
from repro.serving.engine import ServeEngine
from repro.serving.faults import FaultInjector, FaultKind, chaos_schedule
from repro.serving.kv_cache import KVCacheConfig
from repro.serving.scheduler import Scheduler

BS = 4  # KV page size

#: Only the rollback-retry kinds: latency spikes would just add their
#: sleeps to the wall time, and poisons change the output by design.
FAILING = (FaultKind.STEP_FAILURE, FaultKind.OOM)


def _engine(cfg, lm, params, max_seqs, max_blocks_per_seq):
    ccfg = KVCacheConfig(
        n_layers=cfg.n_layers,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd,
        block_size=BS,
        max_seqs=max_seqs,
        max_blocks_per_seq=max_blocks_per_seq,
        dtype=cfg.dtype,
    )
    return ServeEngine(lm, params, ccfg)


def _requests(cfg, n_reqs, n_particles, steps, plen):
    trace = traces_lib.staggered(
        n_reqs, 0, n_particles=n_particles, steps=steps, plen=plen
    )
    return traces_lib.to_decode_requests(
        trace, cfg.vocab_size, target_temp=0.5, token_block_size=BS
    )


def _run_schedule(cfg, lm, params, reqs, max_blocks_per_seq, schedule):
    """Cold pass compiles, warm pass times — same idiom as bench_sched;
    the injector is rebuilt per pass (consumed schedules don't replay)."""
    slots = sum(r.n_particles for r in reqs)
    eng = _engine(cfg, lm, params, slots, max_blocks_per_seq)

    def once():
        sched = Scheduler(eng, faults=FaultInjector(schedule))
        for r in reqs:
            sched.submit(r)
        t0 = time.time()
        res = sched.run()
        return res, sched, time.time() - t0

    once()
    return once()


def run(n_reqs: int = 3, n_particles: int = 6, steps: int = 16, plen: int = 6):
    rows = []
    cfg = smoke_config("musicgen_large")
    lm = LanguageModel(cfg)
    params, _ = lm.init(KEY)
    mbs = -(-(plen + steps) // BS) + 2
    reqs = _requests(cfg, n_reqs, n_particles, steps, plen)
    tokens = sum(r.n_particles * r.steps for r in reqs)

    clean_res = None
    clean_secs = None
    for rate in (0.0, 0.05, 0.20):
        schedule = chaos_schedule(17, steps, rate=rate, kinds=FAILING, max_repeats=2)
        res, sched, secs = _run_schedule(cfg, lm, params, reqs, mbs, schedule)
        if rate == 0.0:
            clean_res, clean_secs = res, secs
            assert sched.stats.faults == 0
        else:
            # The recovery gate: injected transient faults are
            # bit-invisible in every output.
            for r in reqs:
                assert res[r.rid].status == "ok", (rate, r.rid)
                np.testing.assert_array_equal(
                    np.asarray(res[r.rid].tokens),
                    np.asarray(clean_res[r.rid].tokens),
                    err_msg=f"rate={rate} rid={r.rid}: tokens diverged",
                )
                np.testing.assert_array_equal(
                    np.asarray(res[r.rid].log_weights),
                    np.asarray(clean_res[r.rid].log_weights),
                )
                np.testing.assert_array_equal(
                    np.asarray(res[r.rid].log_evidence),
                    np.asarray(clean_res[r.rid].log_evidence),
                )
            assert sched.stats.faults > 0, f"rate={rate}: schedule was empty"
        rows.append(
            emit(
                "faults",
                f"faults_rate{int(rate * 100)}_R{n_reqs}xN{n_particles}",
                secs / (steps * n_reqs),
                f"tokens_per_sec={tokens / secs:.1f};"
                f"faults={sched.stats.faults};retries={sched.stats.retries};"
                f"overhead={secs / clean_secs:.2f}x;recovered=bitexact",
                n_reqs=n_reqs,
                n_particles=n_particles,
                steps=steps,
                fault_rate=rate,
                scheduler=sched.stats.as_dict(),
            )
        )
    return rows


if __name__ == "__main__":
    run()
