"""Beyond-paper: COW-paged KV serving under population-based decoding.

Measures peak live KV blocks (and fork latency) for SMC decoding vs the
dense per-sequence-cache equivalent — the paper's O(DNT) -> sparse claim
transplanted into an LM serving stack.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.model import LanguageModel
from repro.serving.smc_decode import SMCDecoder

from benchmarks.common import KEY, emit


def run(steps: int = 32, prompt_len: int = 16):
    rows = []
    cfg = smoke_config("musicgen_large")
    lm = LanguageModel(cfg)
    params, _ = lm.init(KEY)
    for n in (8, 32, 64):
        dec = SMCDecoder(
            lm, params, n_particles=n, max_len=prompt_len + steps + 16,
            target_temp=0.5, block_size=4,
        )
        prompt = jax.random.randint(KEY, (prompt_len,), 0, cfg.vocab_size)
        t0 = time.time()
        res = dec.run(KEY, prompt, steps=steps)
        secs = time.time() - t0
        dense = dec.dense_equivalent_blocks(steps, prompt_len)
        used = int(res.used_blocks_trace[-1])
        peak = int(np.max(np.asarray(res.used_blocks_trace)))
        rows.append(
            emit(
                "serve",
                f"serving_smc_N{n}",
                secs / steps,
                f"peak_blocks={peak};final_blocks={used};dense_equiv={dense};"
                f"saving={dense / max(peak, 1):.2f}x;"
                f"resampled={int(res.resampled.sum())};steps={steps}",
            )
        )

    # COW-native decode row (DESIGN.md §3.2/§7): with sub-block delta
    # COW on, paged attention resolves shared pages through the pool's
    # parent/dirty leaves in place — the decode loop never materializes
    # KV, and the token-history store is only gathered once, by the
    # end-of-run ``tokens()`` finalize.  The zero-materialize claim is
    # asserted, not just reported.
    from repro.core import store as store_lib

    n = 8
    dec = SMCDecoder(
        lm, params, n_particles=n, max_len=prompt_len + steps + 16,
        target_temp=0.5, block_size=4, kv_delta_cow=True,
    )
    prompt = jax.random.randint(KEY, (prompt_len,), 0, cfg.vocab_size)
    calls = {"materialize_batch": 0}
    real = store_lib.materialize_batch

    def _counting(*a, **k):
        calls["materialize_batch"] += 1
        return real(*a, **k)

    store_lib.materialize_batch = _counting
    try:
        t0 = time.time()
        res = dec.run(KEY, prompt, steps=steps)
        secs = time.time() - t0
    finally:
        store_lib.materialize_batch = real
    decode_materializes = calls["materialize_batch"] - 1  # tokens() finalize
    assert decode_materializes == 0, calls
    peak = int(np.max(np.asarray(res.used_blocks_trace)))
    rows.append(
        emit(
            "serve",
            f"serving_smc_delta_N{n}",
            secs / steps,
            f"peak_blocks={peak};decode_materializes={decode_materializes};"
            f"resampled={int(res.resampled.sum())};steps={steps}",
        )
    )
    return rows


if __name__ == "__main__":
    run()
