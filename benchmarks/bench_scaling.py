"""Paper Figure 7: time and memory as functions of t.

Theory: eager shows quadratic cumulative time and linear memory in t;
lazy shows linear time and slower-growing memory (the sparse bound),
except PCFG (latest-state-only).  We report the per-step memory trace
(from the filter itself) and cumulative wall time at T/4, T/2, 3T/4, T.
"""

from __future__ import annotations


from repro.core.config import CopyMode

from benchmarks.common import build_runner, emit, time_run


def run(n: int = 128, t: int = 64, problems=("rbpf", "mot")):
    rows = []
    for name in problems:
        for mode in (CopyMode.EAGER, CopyMode.LAZY, CopyMode.LAZY_SR):
            times = []
            for frac in (0.25, 0.5, 0.75, 1.0):
                tt = max(4, int(t * frac))
                runner, cfg = build_runner(name, mode, n, tt, simulate=False)
                secs, peak, _ = time_run(runner, reps=2)
                times.append((tt, secs, peak))
            trace = ";".join(f"t{tt}:s={s:.3f}:blk={p}" for tt, s, p in times)
            # growth ratio: time(T) / time(T/2) — ~2 for linear, ~4 quadratic
            growth = times[-1][1] / max(times[1][1], 1e-9)
            rows.append(
                emit(
                    "fig7",
                    f"fig7_scaling_{name}_{mode.value}",
                    times[-1][1],
                    f"growthT/T2={growth:.2f};{trace}",
                )
            )
    return rows


if __name__ == "__main__":
    run()
