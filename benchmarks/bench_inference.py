"""Paper Figure 5: inference-task time and peak memory, 5 problems x
3 configurations (eager / lazy / lazy+single-reference)."""

from __future__ import annotations

from repro.core.config import ALL_MODES
from repro.smc.programs import PROBLEMS

from benchmarks.common import build_runner, emit, time_run


def run(n: int = 128, t: int = 48, reps: int = 3):
    rows = []
    for name in PROBLEMS:
        for mode in ALL_MODES:
            runner, cfg = build_runner(name, mode, n, t, simulate=False)
            secs, peak, logz = time_run(runner, reps)
            block_bytes = cfg.block_size * 4  # f32 items
            rows.append(
                emit(
                    "fig5",
                    f"fig5_inference_{name}_{mode.value}",
                    secs,
                    f"peak_blocks={peak};peak_kb={peak * block_bytes // 1024};"
                    f"logZ={logz:.2f};N={n};T={t}",
                )
            )
    return rows


if __name__ == "__main__":
    run()
