"""Scheduler-simulator validation + capacity projection (DESIGN.md §9).

Two differential rows gate the simulator against the real scheduler on
quick traces: the recorded decision sequence must replay exactly
(``decision_exact=1`` is a baseline-gated bit, and the run asserts it
outright) and the calibrated cost model must predict the warm
device-path wall — the sum of recorded decode/prefill/grow segments,
the portion the model prices — within +/-25% (``time_ratio``; asserted
in-bench, excluded from the cross-host baseline gate).  A third, device-free row replays a large
Poisson trace against the roofline cost model for a production-size
config — its peak blocks, preemption/growth counts, and predicted p99
queueing latency are fully deterministic, so the committed baseline
remembers them bit-for-bit.
"""

from __future__ import annotations

import time

from benchmarks.bench_scheduler import BS, _engine
from benchmarks.common import KEY, emit
from repro.configs import get_config, smoke_config
from repro.models.model import LanguageModel
from repro.serving import traces as traces_lib
from repro.serving.kv_cache import KVCacheConfig
from repro.serving.scheduler import Scheduler, SchedulerEventLog
from repro.serving.sim import CostModel, first_divergence, simulate

TIME_RATIO_TOL = 0.25  # predicted / measured device-path wall, both ways


def _diff_row(cfg, lm, params, label, interval, n_reqs, n_particles, steps, plen):
    trace = traces_lib.staggered(
        n_reqs, interval, n_particles=n_particles, steps=steps, plen=plen
    )
    reqs = traces_lib.to_decode_requests(
        trace, cfg.vocab_size, target_temp=0.5, token_block_size=BS
    )
    mbs = -(-(plen + steps) // BS) + 2
    eng = _engine(cfg, lm, params, sum(r.n_particles for r in reqs), mbs)

    def once(log=None):
        sched = Scheduler(eng, event_log=log)
        for r in reqs:
            sched.submit(r)
        t0 = time.time()
        sched.run()
        return time.time() - t0

    once()  # cold: compile + grow the pool
    pre_blocks = eng.num_blocks
    log = SchedulerEventLog()
    wall = once(log)

    cost = CostModel.from_event_log(log)
    res = simulate(
        log.to_trace(label), eng.cache_cfg, cost, initial_blocks=pre_blocks
    )
    div = first_divergence(log.decisions, res.decisions)
    assert div is None, f"{label}: simulator diverged from recording: {div}"
    assert res.peak_blocks == log.peak_blocks(), (
        f"{label}: peak {res.peak_blocks} != recorded {log.peak_blocks()}"
    )
    ratio = res.sim_time_s / log.recorded_wall_s()
    assert (1 - TIME_RATIO_TOL) <= ratio <= (1 + TIME_RATIO_TOL), (
        f"{label}: predicted/measured device-path ratio {ratio:.2f} "
        f"outside +/-{TIME_RATIO_TOL:.0%}"
    )
    return emit(
        "sim",
        f"sim_diff_{label}_R{n_reqs}xN{n_particles}",
        wall / (steps * n_reqs),
        f"decision_exact=1;peak_blocks={res.peak_blocks};"
        f"events={len(log.decisions)};time_ratio={ratio:.2f}",
        n_reqs=n_reqs,
        n_particles=n_particles,
        steps=steps,
        interval=interval,
    )


def _scale_row(n_reqs: int):
    """Device-free: a big Poisson trace with synthetic fork schedules
    against the §3.1 roofline costs of a production-size config.  Every
    derived number is a deterministic function of (trace seed, cost
    model), so the baseline gates them across hosts."""
    big = get_config("qwen2.5-32b")
    ccfg = KVCacheConfig(
        n_layers=big.n_layers,
        n_kv_heads=big.n_kv_heads,
        head_dim=big.hd,
        block_size=16,
        max_seqs=64,
        max_blocks_per_seq=8,
        dtype=big.dtype,
    )
    trace = traces_lib.with_synthetic_forks(
        traces_lib.poisson(
            n_reqs,
            0.08,
            n_particles=(2, 8),
            steps=(24, 64),
            plen=(8, 48),
            seed=7,
        ),
        p_resample=0.4,
    )
    cost = CostModel.from_roofline(big, ccfg)
    t0 = time.time()
    res = simulate(trace, ccfg, cost)
    host_secs = time.time() - t0
    lat = res.latency_percentiles()
    return emit(
        "sim",
        f"sim_poisson_R{n_reqs}",
        host_secs / n_reqs,
        f"peak_blocks={res.peak_blocks};grow={res.grow_events};"
        f"preempt={res.stats.preemptions};ticks={res.stats.ticks};"
        f"p99_queue_ms={lat['queue_p99_s'] * 1e3:.1f};"
        f"pred_tokens_per_sec={res.tokens_per_sec:.0f}",
        n_reqs=n_reqs,
        seed=trace.seed,
        arch="qwen2.5-32b",
    )


def run(n_reqs: int = 3, n_particles: int = 6, steps: int = 12, plen: int = 6,
        scale_reqs: int = 200):
    rows = []
    cfg = smoke_config("musicgen_large")
    lm = LanguageModel(cfg)
    params, _ = lm.init(KEY)
    for label, interval in (("burst", 0), ("stagger", 2)):
        rows.append(
            _diff_row(
                cfg, lm, params, label, interval, n_reqs, n_particles, steps, plen
            )
        )
    rows.append(_scale_row(scale_reqs))
    return rows


if __name__ == "__main__":
    run()
