"""Continuous-batching SMC serving scheduler (DESIGN.md §8, §12).

Measures aggregate decode throughput (tokens/sec) and peak shared-pool
blocks against request arrival rate: a burst of simultaneous requests
vs the same requests arriving staggered at token-boundary intervals,
all multiplexed over ONE COW page pool and one jitted decode step —
plus the replicated-fleet rows: the same requests routed across two
scheduler replicas, and an SLA scenario comparing preemption policies.

Every row also reports deterministic p50/p99 queue and completion
latency **in ticks** (from the event log — machine-independent, so the
baseline gates them tightly; wall times gate host-normalized as usual).

Gates (the PRs' acceptance criteria):

  * single-request parity — a scheduler run of one request is
    token-bit-exact with the private :class:`SMCDecoder` run;
  * sharing across requests — peak pool blocks stay *below* the sum of
    the requests' dense-equivalent per-sequence caches;
  * replication invisibility — the 2-replica router run is per-request
    token-bit-exact with the single-replica run of the same requests,
    and the simulator mirrors the router's placement decisions exactly
    (``first_divergence`` on the fleet event logs);
  * SLA-aware preemption beats newest-first on miss-penalized p99
    completion latency at the bursty deadline trace, and both policy
    runs replay decision-exact through the simulator.
"""

from __future__ import annotations

import math
import time

import numpy as np

from benchmarks.common import KEY, emit, write_artifact
from repro.configs import smoke_config
from repro.models.model import LanguageModel
from repro.serving import traces as traces_lib
from repro.serving.engine import ServeEngine
from repro.serving.kv_cache import KVCacheConfig
from repro.serving.router import Router, RouterEventLog
from repro.serving.scheduler import Scheduler, SchedulerEventLog
from repro.serving.sim import CostModel, SimScheduler, first_divergence, simulate
from repro.serving.smc_decode import SMCDecoder

BS = 4  # KV page size

# Placeholder cost model for the decision-exactness mirrors (decisions
# are tick-driven; the cost constants never influence them).
SIM_COST = CostModel(
    step_s=1e-3, prefill_s=2e-3, grow_s_per_block=1e-5, compact_s_per_block=1e-5
)

# Terminal event kinds, in the event log's vocabulary.
_TERMINAL = ("complete", "cancel", "expired", "shed", "poisoned")


def _engine(cfg, lm, params, max_seqs, max_blocks_per_seq, num_blocks=0):
    ccfg = KVCacheConfig(
        n_layers=cfg.n_layers,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd,
        block_size=BS,
        max_seqs=max_seqs,
        max_blocks_per_seq=max_blocks_per_seq,
        num_blocks=num_blocks,
        dtype=cfg.dtype,
    )
    return ServeEngine(lm, params, ccfg)


def _trace(n_reqs, n_particles, steps, plen, interval=0):
    return traces_lib.staggered(
        n_reqs, interval, n_particles=n_particles, steps=steps, plen=plen
    )


def _requests(cfg, trace):
    """The bench's arrival patterns come from the shared seeded trace
    generator (``repro.serving.traces``) — the same bytes the simulator
    and tests replay (tests/test_traces.py gates reproducibility)."""
    return traces_lib.to_decode_requests(
        trace, cfg.vocab_size, target_temp=0.5, token_block_size=BS
    )


def _dense_equiv(reqs):
    return sum(
        r.n_particles * -(-(int(r.prompt.shape[0]) + r.steps) // BS)
        for r in reqs
    )


def _lat_str(lat) -> str:
    """Deterministic tick-latency metrics for a row's derived string."""
    return (
        f"queue_p50={lat['queue_p50']:g};queue_p99={lat['queue_p99']:g};"
        f"completion_p50={lat['completion_p50']:g};"
        f"completion_p99={lat['completion_p99']:g}"
    )


def _run_schedule(cfg, lm, params, reqs, max_blocks_per_seq, **sched_kw):
    """Run the schedule twice on one engine: the cold pass compiles (and
    grows the pool — recorded as ``cold_grew``), the warm pass is what
    the timing row reports, so the baseline gate tracks steady-state
    serving throughput rather than compile noise.  The warm pass records
    an event log (tick latency metrics + the simulator mirror)."""
    slots = sum(r.n_particles for r in reqs)
    eng = _engine(cfg, lm, params, slots, max_blocks_per_seq)

    def once(log=None):
        sched = Scheduler(eng, event_log=log, **sched_kw)
        for r in reqs:
            sched.submit(r)
        t0 = time.time()
        res = sched.run()
        return res, sched, time.time() - t0

    _, cold, _ = once()
    log = SchedulerEventLog()
    res, sched, secs = once(log)
    peak = max(int(np.max(np.asarray(res[r.rid].used_blocks_trace))) for r in reqs)
    tokens = sum(r.n_particles * r.steps for r in reqs)
    return res, sched, secs, peak, tokens, cold, log


def _terminal_ticks(log):
    """rid -> (tick, kind) of each request's first terminal event."""
    out = {}
    for e in log.decisions:
        if e[0] in _TERMINAL and e[1] not in out:
            out[e[1]] = (e[2], e[0])
    return out


def _sla_row(cfg, lm, params, n_reqs, n_particles, steps, plen):
    """The SLA scenario: a bursty deadline trace on a fixed pool sized
    to force preemption (45% of the dense-equivalent demand).  Every
    third request carries a tight deadline (1.5x its steps); the rest
    are loose.  Newest-first keeps victimizing the latest admission —
    the tight request — and misses its SLA; the SLA-aware policy evicts
    a loose incumbent instead and makes every deadline.  Gated on
    miss-penalized p99 completion latency (a miss costs ``deadline +
    2*steps`` ticks — deterministic, so the baseline pins it exactly)
    and on decision-exact simulator replay of both policy runs."""
    trace = traces_lib.with_deadlines(
        _trace(n_reqs, n_particles, steps, plen),
        slack_x=12.0,
        floor=4,
        tight_every=3,
        tight_slack_x=1.5,
    )
    reqs = _requests(cfg, trace)
    nb = math.ceil(0.45 * _dense_equiv(reqs))
    slots = sum(r.n_particles for r in reqs)
    mbs = -(-(plen + steps) // BS) + 2
    deadlines = {r.rid: r.deadline for r in trace.requests}
    arrive = {r.rid: r.arrive_at for r in trace.requests}
    stats = {}
    for policy in ("newest", "sla"):
        eng = _engine(cfg, lm, params, slots, mbs, num_blocks=nb)
        log = SchedulerEventLog()
        sched = Scheduler(eng, grow=False, preempt_policy=policy, event_log=log)
        for r in reqs:
            sched.submit(r)
        t0 = time.time()
        sched.run()
        secs = time.time() - t0
        lats, misses = [], 0
        for rid, (tick, kind) in _terminal_ticks(log).items():
            if kind == "complete":
                lats.append(tick - arrive[rid])
            else:
                misses += 1
                lats.append(deadlines[rid] - arrive[rid] + 2 * steps)
        # decision-exactness: the recorded run replays through the
        # simulator with the same policy, divergence-free.
        sim_res = simulate(
            log.to_trace(f"sla_{policy}"),
            eng.cache_cfg,
            SIM_COST,
            grow=False,
            preempt_policy=policy,
        )
        div = first_divergence(log.decisions, sim_res.decisions)
        assert div is None, f"sla_{policy}: simulator diverged: {div}"
        stats[policy] = {
            "p99": float(np.percentile(lats, 99)),
            "p50": float(np.percentile(lats, 50)),
            "misses": misses,
            "preempt": sched.stats.preemptions,
            "secs": secs,
        }
    # gate: the SLA-aware policy beats newest-first where it matters.
    assert stats["sla"]["p99"] < stats["newest"]["p99"], stats
    assert stats["sla"]["misses"] <= stats["newest"]["misses"], stats
    return emit(
        "sched",
        f"sched_sla_bursty_R{n_reqs}xN{n_particles}",
        stats["sla"]["secs"] / (steps * n_reqs),
        f"p99_sla={stats['sla']['p99']:g};p99_newest={stats['newest']['p99']:g};"
        f"miss_sla={stats['sla']['misses']};miss_newest={stats['newest']['misses']};"
        f"preempt_sla={stats['sla']['preempt']};"
        f"preempt_newest={stats['newest']['preempt']}",
        n_reqs=n_reqs,
        n_particles=n_particles,
        steps=steps,
        pool_blocks=nb,
        deadlines={k: v for k, v in deadlines.items()},
    )


def _router_row(cfg, lm, params, reqs, single_res, mbs, n_reqs, n_particles, steps):
    """The replicated-fleet row: the stagger2 requests routed across two
    scheduler replicas.  Gates (1) per-request token-bit-exactness
    against the single-replica run of the same requests and (2) a
    decision-exact fleet mirror — the *same* ``Router`` class drives two
    ``SimScheduler`` replicas over the recorded trace, and the fleet
    event logs must agree event-for-event (placement included)."""
    slots = sum(r.n_particles for r in reqs)
    engines = [_engine(cfg, lm, params, slots, mbs) for _ in range(2)]

    def once(with_logs):
        logs = [SchedulerEventLog() if with_logs else None for _ in engines]
        router = Router(
            [Scheduler(e, event_log=lg) for e, lg in zip(engines, logs)],
            placement="least_loaded",
            event_log=RouterEventLog(),
        )
        for r in reqs:
            router.submit(r)
        t0 = time.time()
        res = router.run()
        return router, res, logs, time.time() - t0

    once(False)  # cold: compile both replicas
    router, res, logs, secs = once(True)

    # gate 1: replication is invisible to results.
    for r in reqs:
        assert np.array_equal(
            np.asarray(res[r.rid].tokens), np.asarray(single_res[r.rid].tokens)
        ), f"router: {r.rid} tokens != single-replica run"

    # gate 2: the simulated fleet mirrors the real fleet's placement.
    spec_by_rid = {}
    for lg in logs:
        spec_by_rid.update(lg.requests)
    merged = traces_lib.Trace(
        name="router_recorded",
        requests=tuple(
            traces_lib.TraceRequest(
                rid=r.rid,
                arrive_at=spec_by_rid[r.rid]["arrive_at"],
                n_particles=spec_by_rid[r.rid]["n_particles"],
                steps=spec_by_rid[r.rid]["steps"],
                plen=spec_by_rid[r.rid]["plen"],
                deadline=spec_by_rid[r.rid]["deadline"],
                forks=dict(spec_by_rid[r.rid]["forks"]),
            )
            for r in reqs  # original submission order
        ),
    )
    sim_router = Router(
        [SimScheduler(engines[0].cache_cfg, SIM_COST) for _ in range(2)],
        placement="least_loaded",
        event_log=RouterEventLog(),
    )
    for r in merged.requests:
        sim_router.submit(r)
    sim_router.run()
    div = first_divergence(router.event_log.events, sim_router.event_log.events)
    assert div is None, f"router: simulated fleet diverged: {div}"

    lat = router.event_log.latency_rounds()
    util = router.utilization()
    write_artifact(
        "router_utilization.json",
        {
            "rounds": router.round,
            "placement": router.placement_name,
            "latency_rounds": lat,
            "replicas": util,
        },
    )
    tokens = sum(r.n_particles * r.steps for r in reqs)
    return emit(
        "sched",
        f"sched_router2_R{n_reqs}xN{n_particles}",
        secs / (steps * n_reqs),
        f"tokens_per_sec={tokens / secs:.1f};rounds={router.round};"
        f"placed0={util[0]['placed']};placed1={util[1]['placed']};"
        f"rq_p99={lat['queue_p99']:g};rc_p99={lat['completion_p99']:g};"
        f"parity=exact",
        n_reqs=n_reqs,
        n_particles=n_particles,
        steps=steps,
        replicas=2,
        placement="least_loaded",
    )


def run(n_reqs: int = 4, n_particles: int = 8, steps: int = 16, plen: int = 6):
    rows = []
    cfg = smoke_config("musicgen_large")
    lm = LanguageModel(cfg)
    params, _ = lm.init(KEY)
    mbs = -(-(plen + steps) // BS) + 2
    reqs = _requests(cfg, _trace(n_reqs, n_particles, steps, plen))

    # -- gate 1: single-request parity (scheduler == private decoder) --------
    dec = SMCDecoder(
        lm,
        params,
        n_particles=n_particles,
        max_len=plen + steps + 16,
        target_temp=0.5,
        block_size=BS,
    )
    ref = dec.run(reqs[0].key, reqs[0].prompt, steps)
    solo, _, solo_secs, solo_peak, solo_tokens, _, solo_log = _run_schedule(
        cfg, lm, params, reqs[:1], mbs
    )
    assert np.array_equal(
        np.asarray(solo["r0"].tokens), np.asarray(ref.tokens)
    ), "single-request parity gate: scheduler tokens != SMCDecoder tokens"
    rows.append(
        emit(
            "sched",
            f"sched_solo_N{n_particles}",
            solo_secs / steps,
            f"tokens_per_sec={solo_tokens / solo_secs:.1f};"
            f"peak_blocks={solo_peak};parity=exact;"
            + _lat_str(solo_log.latency_ticks()),
            n_reqs=1,
            n_particles=n_particles,
            steps=steps,
        )
    )

    # -- arrival-rate sweep over one shared pool -----------------------------
    dense = _dense_equiv(reqs)
    stagger2 = None  # (requests, results) — reused by the router row
    for label, interval in (("burst", 0), ("stagger2", 2), ("stagger6", 6)):
        arr = _requests(cfg, _trace(n_reqs, n_particles, steps, plen, interval))
        res, sched, secs, peak, tokens, cold, log = _run_schedule(
            cfg, lm, params, arr, mbs
        )
        if label == "stagger2":
            stagger2 = (arr, res)
        for r in arr:
            assert not bool(res[r.rid].oom), (label, r.rid)
        # gate 2: COW sharing across the population of populations —
        # the shared pool's peak must undercut per-request dense caches.
        assert peak < dense, (
            f"{label}: peak {peak} >= dense-equivalent sum {dense}"
        )
        rows.append(
            emit(
                "sched",
                f"sched_{label}_R{n_reqs}xN{n_particles}",
                secs / (steps * n_reqs),
                f"tokens_per_sec={tokens / secs:.1f};peak_blocks={peak};"
                f"dense_equiv={dense};saving={dense / max(peak, 1):.2f}x;"
                f"preempt={sched.stats.preemptions};"
                f"ticks={sched.stats.ticks};"
                + _lat_str(log.latency_ticks()),
                n_reqs=n_reqs,
                n_particles=n_particles,
                steps=steps,
                arrival_interval=interval,
                cold_grew=cold.executor.stats.grow_events,
                scheduler=sched.stats.as_dict(),
            )
        )

    # -- replicated fleet (DESIGN.md §12) ------------------------------------
    rows.append(
        _router_row(
            cfg, lm, params, stagger2[0], stagger2[1], mbs,
            n_reqs, n_particles, steps,
        )
    )

    # -- SLA-aware preemption vs newest-first --------------------------------
    rows.append(_sla_row(cfg, lm, params, n_reqs, n_particles, steps, plen))
    return rows


if __name__ == "__main__":
    run()
