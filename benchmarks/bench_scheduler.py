"""Continuous-batching SMC serving scheduler (DESIGN.md §8).

Measures aggregate decode throughput (tokens/sec) and peak shared-pool
blocks against request arrival rate: a burst of simultaneous requests
vs the same requests arriving staggered at token-boundary intervals,
all multiplexed over ONE COW page pool and one jitted decode step.

Gates (the PR's acceptance criteria):

  * single-request parity — a scheduler run of one request is
    token-bit-exact with the private :class:`SMCDecoder` run;
  * sharing across requests — peak pool blocks stay *below* the sum of
    the requests' dense-equivalent per-sequence caches.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import KEY, emit
from repro.configs import smoke_config
from repro.models.model import LanguageModel
from repro.serving import traces as traces_lib
from repro.serving.engine import ServeEngine
from repro.serving.kv_cache import KVCacheConfig
from repro.serving.smc_decode import SMCDecoder
from repro.serving.scheduler import Scheduler

BS = 4  # KV page size


def _engine(cfg, lm, params, max_seqs, max_blocks_per_seq):
    ccfg = KVCacheConfig(
        n_layers=cfg.n_layers,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd,
        block_size=BS,
        max_seqs=max_seqs,
        max_blocks_per_seq=max_blocks_per_seq,
        dtype=cfg.dtype,
    )
    return ServeEngine(lm, params, ccfg)


def _requests(cfg, n_reqs, n_particles, steps, plen, interval=0):
    """The bench's arrival patterns come from the shared seeded trace
    generator (``repro.serving.traces``) — the same bytes the simulator
    and tests replay (tests/test_traces.py gates reproducibility)."""
    trace = traces_lib.staggered(
        n_reqs, interval, n_particles=n_particles, steps=steps, plen=plen
    )
    return traces_lib.to_decode_requests(
        trace, cfg.vocab_size, target_temp=0.5, token_block_size=BS
    )


def _dense_equiv(reqs):
    return sum(
        r.n_particles * -(-(int(r.prompt.shape[0]) + r.steps) // BS)
        for r in reqs
    )


def _run_schedule(cfg, lm, params, reqs, max_blocks_per_seq):
    """Run the schedule twice on one engine: the cold pass compiles (and
    grows the pool — recorded as ``cold_grew``), the warm pass is what
    the timing row reports, so the baseline gate tracks steady-state
    serving throughput rather than compile noise."""
    slots = sum(r.n_particles for r in reqs)
    eng = _engine(cfg, lm, params, slots, max_blocks_per_seq)

    def once():
        sched = Scheduler(eng)
        for r in reqs:
            sched.submit(r)
        t0 = time.time()
        res = sched.run()
        return res, sched, time.time() - t0

    _, cold, _ = once()
    res, sched, secs = once()
    peak = max(int(np.max(np.asarray(res[r.rid].used_blocks_trace))) for r in reqs)
    tokens = sum(r.n_particles * r.steps for r in reqs)
    return res, sched, secs, peak, tokens, cold


def run(n_reqs: int = 4, n_particles: int = 8, steps: int = 16, plen: int = 6):
    rows = []
    cfg = smoke_config("musicgen_large")
    lm = LanguageModel(cfg)
    params, _ = lm.init(KEY)
    mbs = -(-(plen + steps) // BS) + 2
    reqs = _requests(cfg, n_reqs, n_particles, steps, plen)

    # -- gate 1: single-request parity (scheduler == private decoder) --------
    dec = SMCDecoder(
        lm,
        params,
        n_particles=n_particles,
        max_len=plen + steps + 16,
        target_temp=0.5,
        block_size=BS,
    )
    ref = dec.run(reqs[0].key, reqs[0].prompt, steps)
    solo, _, solo_secs, solo_peak, solo_tokens, _ = _run_schedule(
        cfg, lm, params, reqs[:1], mbs
    )
    assert np.array_equal(
        np.asarray(solo["r0"].tokens), np.asarray(ref.tokens)
    ), "single-request parity gate: scheduler tokens != SMCDecoder tokens"
    rows.append(
        emit(
            "sched",
            f"sched_solo_N{n_particles}",
            solo_secs / steps,
            f"tokens_per_sec={solo_tokens / solo_secs:.1f};"
            f"peak_blocks={solo_peak};parity=exact",
            n_reqs=1,
            n_particles=n_particles,
            steps=steps,
        )
    )

    # -- arrival-rate sweep over one shared pool -----------------------------
    dense = _dense_equiv(reqs)
    for label, interval in (("burst", 0), ("stagger2", 2), ("stagger6", 6)):
        arr = _requests(cfg, n_reqs, n_particles, steps, plen, interval=interval)
        res, sched, secs, peak, tokens, cold = _run_schedule(cfg, lm, params, arr, mbs)
        for r in arr:
            assert not bool(res[r.rid].oom), (label, r.rid)
        # gate 2: COW sharing across the population of populations —
        # the shared pool's peak must undercut per-request dense caches.
        assert peak < dense, (
            f"{label}: peak {peak} >= dense-equivalent sum {dense}"
        )
        rows.append(
            emit(
                "sched",
                f"sched_{label}_R{n_reqs}xN{n_particles}",
                secs / (steps * n_reqs),
                f"tokens_per_sec={tokens / secs:.1f};peak_blocks={peak};"
                f"dense_equiv={dense};saving={dense / max(peak, 1):.2f}x;"
                f"preempt={sched.stats.preemptions};"
                f"ticks={sched.stats.ticks}",
                n_reqs=n_reqs,
                n_particles=n_particles,
                steps=steps,
                arrival_interval=interval,
                cold_grew=cold.executor.stats.grow_events,
                scheduler=sched.stats.as_dict(),
            )
        )
    return rows


if __name__ == "__main__":
    run()
