"""Sharded-population scaling benchmark (DESIGN.md §6).

Runs the bootstrap filter with the population split over a faked
multi-device host mesh (``--xla_force_host_platform_device_count``) and
reports, per (shard count, copy mode):

  * throughput in particle-steps/sec (N * T / median wall time),
  * per-shard blocks-in-use at the end and the per-shard running peak —
    the paper's memory metric, now resolved per device (imports land on
    the importing shard, so skew shows up here),
  * the log-evidence estimate, checked against the single-device run.

A 1-shard mesh is bit-exact with the single-device path; multi-shard
runs use independent per-shard propagation noise and must agree
statistically.  The final row reports that check: the 4-shard LAZY_SR
log-likelihood vs. the single-device estimate.

Run:  PYTHONPATH=src python benchmarks/bench_sharded.py
(or through ``benchmarks/run.py --only sharded``; note this module must
be imported before anything initializes jax, because the device-count
flag only takes effect at first initialization).
"""

from __future__ import annotations

import os

_FLAGS = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _FLAGS:
    os.environ["XLA_FLAGS"] = (
        _FLAGS + " --xla_force_host_platform_device_count=4"
    ).strip()

import math
import time

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.config import ALL_MODES, CopyMode
from repro.distributed import sharded_store as sharded_lib
from repro.smc.filters import FilterConfig, ParticleFilter, SSMDef

if __package__ in (None, ""):  # invoked as a file path (the documented usage)
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.common import emit

A, Q, R = 0.9, 0.5, 0.3
KEY = jax.random.PRNGKey(0)


def lgssm_def() -> SSMDef:
    def init(key, n, params):
        return jax.random.normal(key, (n,))

    def step(key, x, t, y_t, params):
        x = A * x + math.sqrt(Q) * jax.random.normal(key, x.shape)
        logw = -0.5 * ((y_t - x) ** 2 / R + math.log(2 * math.pi * R))
        return x, logw, x[:, None]

    return SSMDef(init=init, step=step, record_shape=(1,))


def _time(fn, key, obs, reps: int) -> tuple[float, object]:
    res = fn(key, None, obs)  # warmup / compile
    jax.block_until_ready(res.log_evidence)
    times = []
    for i in range(reps):
        t0 = time.time()
        res = fn(jax.random.PRNGKey(i), None, obs)
        jax.block_until_ready(res.log_evidence)
        times.append(time.time() - t0)
    return float(np.median(times)), res


def run(n: int = 256, t: int = 48, reps: int = 3, tol: float = 3.0):
    devices = jax.devices()
    max_shards = len(devices)
    obs = jax.random.normal(KEY, (t,))
    rows = []

    # single-device reference (no mesh at all)
    pf0 = ParticleFilter(
        lgssm_def(),
        FilterConfig(n_particles=n, n_steps=t, mode=CopyMode.LAZY_SR, block_size=2),
    )
    secs0, res0 = _time(pf0.jitted(), KEY, obs, reps)
    ref_logz = float(res0.log_evidence)
    rows.append(
        emit(
            "sharded",
            "sharded_single_device_lazy_sr",
            secs0,
            f"pps={n * t / secs0:.0f};logZ={ref_logz:.3f};"
            f"peak={int(res0.store.peak_blocks)}",
            n=n, t=t,
        )
    )

    shard_counts = [s for s in (1, 2, 4) if s <= max_shards and n % s == 0]
    logz_by_cfg = {}
    for s in shard_counts:
        mesh = Mesh(np.array(devices[:s]), ("shards",))
        for mode in ALL_MODES:
            pf = ParticleFilter(
                lgssm_def(),
                FilterConfig(
                    n_particles=n, n_steps=t, mode=mode, block_size=2, mesh=mesh
                ),
            )
            secs, res = _time(pf.jitted(), KEY, obs, reps)
            shcfg = pf.sharded_cfg
            used = np.asarray(sharded_lib.used_blocks_per_shard(shcfg, res.store))
            peak = np.asarray(sharded_lib.peak_blocks_per_shard(shcfg, res.store))
            oom = bool(np.asarray(res.store.pool.oom).any())
            logz = float(res.log_evidence)
            logz_by_cfg[(s, mode)] = logz
            rows.append(
                emit(
                    "sharded",
                    f"sharded_s{s}_{mode.value}",
                    secs,
                    f"pps={n * t / secs:.0f};logZ={logz:.3f};"
                    f"used_per_shard={'/'.join(map(str, used))};"
                    f"peak_per_shard={'/'.join(map(str, peak))};oom={int(oom)}",
                    n=n, t=t, shards=s, mode=mode.value,
                )
            )

    # the acceptance check: multi-shard LAZY_SR vs single-device logZ
    s_chk = shard_counts[-1]
    delta = abs(logz_by_cfg[(s_chk, CopyMode.LAZY_SR)] - ref_logz)
    verdict = "ok" if delta < tol else "FAIL"
    rows.append(
        emit(
            "sharded",
            f"sharded_logz_check_s{s_chk}",
            0.0,
            f"delta={delta:.3f};tol={tol};verdict={verdict}",
        )
    )
    if verdict == "FAIL":
        raise SystemExit(
            f"{s_chk}-shard LAZY_SR logZ diverged from single-device: "
            f"{delta:.3f} > {tol}"
        )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--t", type=int, default=48)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--json", default="")
    args = ap.parse_args()
    if args.json:
        from benchmarks import common

        common.enable_json(args.json)
    print("name,us_per_call,derived")
    run(n=args.n, t=args.t, reps=args.reps)
    if args.json:
        common.flush_json()
