"""Pool lifecycle benchmark: grow-from-tiny vs oversized-fixed, and
compaction / shrink-to-fit (DESIGN.md §3.1).

Two workloads, both the paper's motivating resample-every-generation
pattern on an LGSSM:

* **grow** — the filter starts on a deliberately tiny pool and relies on
  the generation-boundary lifecycle loop (`FilterConfig.grow`) to reach
  the end; timed against the same run on an oversized fixed pool.  The
  gate is correctness, not speed: identical ``log_evidence`` (growth is
  observationally invisible), no surfaced OOM, and ≥ 1 growth event.
  The wall-clock ratio prices the shape-keyed recompiles the growth
  events cost — this is the number that says whether "start small and
  grow" is a deployable default.

* **compact** — a fig6-style run (simulation task: no resampling, no
  copies, so live blocks are exactly the population's own trajectories)
  followed by ``store.compact`` with shrink-to-fit.  Gates: trajectories
  bit-exact before/after, and post-compaction capacity — the bound on
  every future ``blocks_in_use`` peak — within 1.25x of the live set.
  An inference-shaped variant (clones every generation, so the pool is
  fragmented by COW churn) is emitted alongside.

Roofline model rows (:func:`repro.roofline.write_path.grow_cost` /
``compact_cost``) are emitted next to the wall-clock rows so the JSON
artifacts track the analytic cost too.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pool as pool_lib
from repro.core import store as store_lib
from repro.core.config import CopyMode
from repro.roofline.write_path import compact_cost, grow_cost
from repro.smc.filters import FilterConfig, ParticleFilter

from benchmarks.common import emit, lgssm_def

KEY = jax.random.PRNGKey(0)


def _time(fn, reps: int) -> float:
    fn()  # warmup: compiles (including the growth sequence's shapes)
    times = []
    for _ in range(reps):
        t0 = time.time()
        out = fn()
        jax.block_until_ready(out.log_evidence)
        times.append(time.time() - t0)
    return float(np.median(times))


def run(n: int = 128, t: int = 48, reps: int = 3):
    rows = []
    ys = jax.random.normal(KEY, (t,))
    base = dict(n_particles=n, n_steps=t, mode=CopyMode.LAZY_SR, block_size=4)

    # -- grow: tiny seed pool + lifecycle loop vs oversized fixed pool ------
    seed_blocks = max(2 * n // 4, 16)  # way under the sparse bound
    fixed = ParticleFilter(lgssm_def(), FilterConfig(**base))
    grown = ParticleFilter(
        lgssm_def(),
        FilterConfig(**base, pool_blocks=seed_blocks, grow=True, grow_chunk=8),
    )
    fixed_fn = fixed.jitted()
    grown_fn = grown.jitted()
    secs_fixed = _time(lambda: fixed_fn(KEY, None, ys), reps)
    secs_grown = _time(lambda: grown_fn(KEY, None, ys), reps)
    res_fixed = fixed_fn(KEY, None, ys)
    res_grown = grown_fn(KEY, None, ys)
    assert not bool(res_grown.oom) and int(res_grown.grew) >= 1, (
        "growth run must complete via generation-boundary growth",
        int(res_grown.grew),
        bool(res_grown.oom),
    )
    assert float(res_grown.log_evidence) == float(res_fixed.log_evidence), (
        "growth must be observationally invisible",
        float(res_grown.log_evidence),
        float(res_fixed.log_evidence),
    )
    live = int(pool_lib.blocks_in_use(res_grown.store.pool))
    rows.append(
        emit(
            "pool",
            f"pool_grow_N{n}_T{t}",
            secs_grown,
            f"fixed_us={secs_fixed * 1e6:.0f};"
            f"overhead={secs_grown / max(secs_fixed, 1e-9):.2f}x;"
            f"grew={int(res_grown.grew)};seed_blocks={seed_blocks};"
            f"final_blocks={res_grown.store.pool.num_blocks};"
            f"fixed_blocks={fixed.store_cfg.pool_blocks};live={live}",
            n=n,
            t=t,
            seed_blocks=seed_blocks,
        )
    )

    # -- compact: shrink-to-fit after fig6-style and fig5-style runs --------
    for task, simulate in (("fig6_sim", True), ("fig5_inf", False)):
        pf = ParticleFilter(lgssm_def(), FilterConfig(**base))
        res = pf.jitted(simulate=simulate)(KEY, None, ys)
        scfg = pf.store_cfg
        store = res.store
        live = int(pool_lib.blocks_in_use(store.pool))
        cap_before = store.pool.num_blocks
        before = np.asarray(store_lib.materialize_batch(scfg, store, jnp.arange(n)))
        # Shrink to exactly the live set — only possible because the
        # relocation densifies it (free and live ids interleave after
        # COW churn, so a slice could never do this).  Warm once so the
        # timed call measures relocation, not first-call dispatch.
        target = live
        store_lib.compact(scfg, store, new_num_blocks=target)
        t0 = time.time()
        compacted = store_lib.compact(scfg, store, new_num_blocks=target)
        jax.block_until_ready(compacted.pool.data)
        secs_c = time.time() - t0
        after = np.asarray(
            store_lib.materialize_batch(scfg, compacted, jnp.arange(n))
        )
        np.testing.assert_array_equal(before, after)  # bit-exact, always
        cap_after = compacted.pool.num_blocks
        assert not bool(compacted.pool.oom)
        # The acceptance gate: post-compaction capacity (the ceiling on
        # every future blocks_in_use peak) within 1.25x of the live set.
        assert cap_after <= 1.25 * live, (task, cap_after, live)
        rows.append(
            emit(
                "pool",
                f"pool_compact_{task}_N{n}_T{t}",
                secs_c,
                f"live={live};cap_before={cap_before};cap_after={cap_after};"
                f"fit={cap_after / max(live, 1):.2f}x",
                n=n,
                t=t,
                task=task,
            )
        )

    # -- roofline model rows ------------------------------------------------
    block_bytes = 4 * 4  # float32 items, block_size=4
    g = grow_cost(old_blocks=seed_blocks, block_bytes=block_bytes)
    c = compact_cost(
        live=live,
        num_blocks=fixed.store_cfg.pool_blocks,
        table_entries=n * fixed.store_cfg.max_blocks,
        block_bytes=block_bytes,
    )
    rows.append(
        emit(
            "pool",
            f"pool_model_N{n}_T{t}",
            0.0,
            f"grow_bytes={g.bytes};grow_passes={g.passes};"
            f"compact_bytes={c.bytes};compact_passes={c.passes}",
            n=n,
            t=t,
        )
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
