# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   fig5  — inference time/memory, 5 problems x 3 copy configurations
#   fig6  — simulation overhead (no copies)
#   fig7  — time/memory scaling in t
#   tree  — Jacob et al. reachable-set bound
#   serve — beyond-paper: COW-paged KV under SMC decoding
#   sharded — beyond-paper: multi-device population (DESIGN.md §4)
#
# ``--quick`` shrinks N/T for CI-speed runs; default sizes run in
# minutes on a CPU host.  The at-scale numbers live in the dry-run
# roofline tables (results/, EXPERIMENTS.md), not here.

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--only", default="",
        help="comma list of {fig5,fig6,fig7,tree,serve,block,sharded}",
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (
        bench_block_size,
        bench_inference,
        bench_scaling,
        bench_serving,
        bench_simulation,
        bench_tree_bound,
    )

    n, t = (48, 24) if args.quick else (128, 48)
    print("name,us_per_call,derived")
    if only is None or "fig5" in only:
        bench_inference.run(n=n, t=t, reps=2 if args.quick else 3)
    if only is None or "fig6" in only:
        bench_simulation.run(n=n, t=t, reps=2 if args.quick else 3)
    if only is None or "fig7" in only:
        bench_scaling.run(n=n, t=2 * t)
    if only is None or "tree" in only:
        bench_tree_bound.run(t=40 if args.quick else 100)
    if only is None or "serve" in only:
        bench_serving.run(steps=16 if args.quick else 32)
    if only is None or "block" in only:
        bench_block_size.run(n=n, t=2 * t)
    if only is None or "sharded" in only:
        # Subprocess: bench_sharded fakes a multi-device host via
        # XLA_FLAGS, which must not leak into the other benchmarks'
        # timings (same isolation idiom as the multi-device tests).
        import pathlib
        import subprocess
        import sys

        subprocess.run(
            [
                sys.executable,
                str(pathlib.Path(__file__).resolve().parent / "bench_sharded.py"),
                f"--n={n * 2}",
                f"--t={t}",
                f"--reps={2 if args.quick else 3}",
            ],
            check=True,
        )


if __name__ == "__main__":
    main()
