# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   fig5  — inference time/memory, 5 problems x 3 copy configurations
#   fig6  — simulation overhead (no copies)
#   fig7  — time/memory scaling in t
#   tree  — Jacob et al. reachable-set bound
#   serve — beyond-paper: COW-paged KV under SMC decoding
#   sharded — beyond-paper: multi-device population (DESIGN.md §6)
#   write — the kernelized COW write path vs the legacy jnp path
#           (DESIGN.md §3; includes the roofline byte/pass gate)
#   pool  — pool lifecycle: grow-from-tiny vs oversized-fixed and
#           compaction/shrink-to-fit (DESIGN.md §3.1; gates logZ
#           equality, bit-exact compaction, and the 1.25x fit bound)
#   pgibbs — particle Gibbs through the shared population executor
#           (DESIGN.md §4): iterations/sec + peak blocks per copy mode,
#           logZ sanity vs the plain filter, and the chunk-cache gate
#           (repeated runs must trigger zero recompiles; compile counts
#           land in the JSON artifacts)
#   sched — continuous-batching SMC serving scheduler (DESIGN.md §8):
#           tokens/sec + peak shared-pool blocks vs request arrival
#           rate; gates single-request parity (bit-exact tokens) and
#           peak < sum of per-request dense-equivalent caches
#   sim   — scheduler simulator validation (DESIGN.md §9): gates
#           decision-exact replay of recorded runs and +/-25% wall-time
#           prediction, plus a device-free Poisson capacity row whose
#           deterministic outputs the baseline remembers bit-for-bit
#   faults — fault-injection overhead (DESIGN.md §10): tokens/sec at
#           0/5/20% injected transient-fault rates; gates bit-exact
#           recovery (faulted runs == fault-free run in every output)
#
# ``--quick`` shrinks N/T for CI-speed runs; default sizes run in
# minutes on a CPU host.  The at-scale numbers live in the dry-run
# roofline tables (results/, EXPERIMENTS.md), not here.
#
# ``--json DIR`` additionally writes one machine-readable
# ``DIR/BENCH_<suite>.json`` per suite (name, us_per_call, derived,
# config per row) so the perf trajectory is trackable across PRs; CI
# uploads these as artifacts.

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--only", default="",
        help="comma list of {fig5,fig6,fig7,tree,serve,block,sharded,write,"
        "pool,pgibbs,sched,sim,faults}",
    )
    ap.add_argument(
        "--json", default="",
        help="directory to write BENCH_<suite>.json result files into",
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import common

    if args.json:
        common.enable_json(args.json)

    n, t = (48, 24) if args.quick else (128, 48)
    print("name,us_per_call,derived")
    try:
        _run_suites(args, only, n, t)
    finally:
        # Flush whatever completed even when a suite (e.g. the write-path
        # perf gate) fails — those are the runs whose evidence matters.
        if args.json:
            common.flush_json()


def _run_suites(args, only, n: int, t: int) -> None:
    from benchmarks import (
        bench_block_size,
        bench_inference,
        bench_pgibbs,
        bench_pool_lifecycle,
        bench_scaling,
        bench_scheduler,
        bench_serving,
        bench_simulation,
        bench_tree_bound,
        bench_write_path,
    )

    if only is None or "fig5" in only:
        bench_inference.run(n=n, t=t, reps=2 if args.quick else 3)
    if only is None or "fig6" in only:
        bench_simulation.run(n=n, t=t, reps=2 if args.quick else 3)
    if only is None or "fig7" in only:
        bench_scaling.run(n=n, t=2 * t)
    if only is None or "tree" in only:
        bench_tree_bound.run(t=40 if args.quick else 100)
    if only is None or "serve" in only:
        bench_serving.run(steps=16 if args.quick else 32)
    if only is None or "block" in only:
        bench_block_size.run(n=n, t=2 * t)
    if only is None or "write" in only:
        bench_write_path.run(quick=args.quick, reps=2 if args.quick else 3)
    if only is None or "pool" in only:
        bench_pool_lifecycle.run(
            n=n // 2 if args.quick else n, t=t, reps=2 if args.quick else 3
        )
    if only is None or "pgibbs" in only:
        bench_pgibbs.run(
            n=n // 2 if args.quick else n,
            t=t,
            iters=2 if args.quick else 3,
            reps=2 if args.quick else 3,
        )
    if only is None or "sched" in only:
        bench_scheduler.run(
            n_reqs=3 if args.quick else 4,
            n_particles=6 if args.quick else 8,
            steps=12 if args.quick else 24,
        )
    if only is None or "sim" in only:
        from benchmarks import bench_sim

        bench_sim.run(
            n_reqs=3,
            n_particles=6,
            steps=12,
            scale_reqs=120 if args.quick else 300,
        )
    if only is None or "faults" in only:
        from benchmarks import bench_faults

        bench_faults.run(
            n_reqs=2 if args.quick else 3,
            n_particles=6,
            steps=12 if args.quick else 16,
        )
    if only is None or "sharded" in only:
        # Subprocess: bench_sharded fakes a multi-device host via
        # XLA_FLAGS, which must not leak into the other benchmarks'
        # timings (same isolation idiom as the multi-device tests).  It
        # writes its own BENCH_sharded.json when --json is set.
        import pathlib
        import subprocess
        import sys

        cmd = [
            sys.executable,
            str(pathlib.Path(__file__).resolve().parent / "bench_sharded.py"),
            f"--n={n * 2}",
            f"--t={t}",
            f"--reps={2 if args.quick else 3}",
        ]
        if args.json:
            cmd.append(f"--json={args.json}")
        subprocess.run(cmd, check=True)


if __name__ == "__main__":
    main()
