"""Particle Gibbs benchmark: iterations/sec + peak blocks per copy mode,
logZ sanity vs the plain filter, and the executor chunk-cache gate.

Three rows per copy mode (EAGER / LAZY / LAZY_SR) on the reference
LGSSM, all the paper's resample-every-generation pattern:

* wall-clock per CSMC sweep iteration and ``peak_blocks`` — the lazy
  modes must land under the eager dense bound, same separation as the
  filter benches;
* **logZ sanity**: all modes estimate the same evidence as a plain
  ``ParticleFilter`` on the same data (the sweep is the filter's scan
  with the reference lineage pinned — a wildly different logZ means the
  port broke the estimator);
* **the chunk-cache gate** (DESIGN.md §4): a repeated
  ``ParticleGibbs.run`` must trigger **zero** executor recompiles — the
  regression guard for the old ``jax.jit(self._csmc)``-per-call bug.
  Compile counts land in the bench JSON (``derived`` and ``config``),
  so the artifact trajectory tracks compiles-per-run across PRs.

A ``grow`` row runs the same workload from a deliberately tiny pool
through the lifecycle loop and gates logZ equality with the fixed-pool
run (growth must be observationally invisible, like ``bench_pool``).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.config import ALL_MODES
from repro.smc.filters import FilterConfig, ParticleFilter
from repro.smc.pgibbs import ParticleGibbs

from benchmarks.common import emit, lgssm_def

KEY = jax.random.PRNGKey(0)


def _time_run(pg, ys, iters: int, reps: int):
    out = pg.run(KEY, None, ys, n_iters=iters)  # warmup (compiles)
    jax.block_until_ready(out.log_evidences)
    times = []
    for i in range(reps):
        t0 = time.time()
        out = pg.run(jax.random.PRNGKey(i), None, ys, n_iters=iters)
        jax.block_until_ready(out.log_evidences)
        times.append(time.time() - t0)
    return float(np.median(times)), out


def run(n: int = 128, t: int = 48, iters: int = 3, reps: int = 3):
    rows = []
    ys = jax.random.normal(KEY, (t,))
    base = dict(n_particles=n, n_steps=t, block_size=4)

    # The sanity anchor: a plain filter's logZ on the same data.
    pf = ParticleFilter(lgssm_def(), FilterConfig(**base))
    pf_logz = float(pf.jitted()(KEY, None, ys).log_evidence)

    fixed_logz = {}
    for mode in ALL_MODES:
        pg = ParticleGibbs(lgssm_def(), FilterConfig(**base, mode=mode))
        secs, out = _time_run(pg, ys, iters, reps)
        warm_compiles = pg.executor.stats.compiles
        pg.run(KEY, None, ys, n_iters=iters)
        compiles = pg.executor.stats.compiles
        # The chunk-cache gate: repeated runs must not re-trace the sweep.
        assert compiles == warm_compiles, (
            "repeated ParticleGibbs.run recompiled the sweep",
            compiles,
            warm_compiles,
        )
        logz = float(out.log_evidences[-1])
        fixed_logz[mode] = logz
        # logZ sanity: the CSMC sweep estimates the same evidence as the
        # plain filter (both are SMC on the same model/data).
        assert abs(logz - pf_logz) < max(10.0, 0.25 * abs(pf_logz)), (
            mode,
            logz,
            pf_logz,
        )
        assert not bool(out.oom)
        peak = int(np.asarray(out.peak_blocks).max())
        rows.append(
            emit(
                "pgibbs",
                f"pgibbs_{mode.name.lower()}_N{n}_T{t}",
                secs / iters,
                f"iters_per_s={iters / max(secs, 1e-9):.2f};"
                f"peak_blocks={peak};logz={logz:.2f};pf_logz={pf_logz:.2f};"
                f"compiles={compiles};grew={int(out.grew)}",
                n=n,
                t=t,
                iters=iters,
                mode=mode.name,
                executor=pg.executor.stats.as_dict(),
            )
        )

    # -- grow: tiny seed pool + lifecycle loop, must match fixed logZ -------
    seed_blocks = max(2 * n // 4, 16)  # way under the sparse bound
    pg = ParticleGibbs(
        lgssm_def(),
        FilterConfig(**base, pool_blocks=seed_blocks, grow=True, grow_chunk=8),
    )
    secs, out = _time_run(pg, ys, iters, reps)
    assert not bool(out.oom) and int(out.grew) >= 1, (
        "growth run must complete via generation-boundary growth",
        int(out.grew),
        bool(out.oom),
    )
    from repro.core.config import CopyMode

    assert float(out.log_evidences[-1]) == fixed_logz[CopyMode.LAZY_SR], (
        "growth must be observationally invisible",
        float(out.log_evidences[-1]),
        fixed_logz[CopyMode.LAZY_SR],
    )
    rows.append(
        emit(
            "pgibbs",
            f"pgibbs_grow_N{n}_T{t}",
            secs / iters,
            f"iters_per_s={iters / max(secs, 1e-9):.2f};"
            f"grew={int(out.grew)};seed_blocks={seed_blocks};"
            f"peak_blocks={int(np.asarray(out.peak_blocks).max())};"
            f"compiles={pg.executor.stats.compiles}",
            n=n,
            t=t,
            iters=iters,
            seed_blocks=seed_blocks,
            executor=pg.executor.stats.as_dict(),
        )
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
