"""Write-path benchmark: µs/append and µs/clone, legacy vs kernelized.

Times the pre-kernelization six-pass jnp write path (reconstructed here:
``nonzero`` free-scan alloc, dense source gather, masked copy scatter,
separate item scatter, chained clone bookkeeping) against the current
fused path (free-stack alloc + ``cow_write`` + ``refcount_update``,
DESIGN.md §3) across N and block_size.

On CPU hosts the Pallas kernels run in interpret mode — wall-clocking
them measures the interpreter, not the kernel — so the kernel path's
advantage is asserted through the roofline byte/pass model
(:mod:`repro.roofline.write_path`): at N >= 1024 with auto-sized pools
the kernel must move >= 2x fewer bytes and make >= 2x fewer HBM passes
per append than the legacy jnp path.  The model rows are emitted next to
the wall-clock rows so the trajectory is trackable from the JSON
artifacts.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pool as pool_lib
from repro.core import store as store_lib
from repro.core.config import CopyMode
from repro.core.pool import NULL_BLOCK
from repro.core.store import StoreConfig
from repro.roofline.write_path import append_cost, chain_cost, clone_cost
from repro.smc import resampling

from benchmarks.common import emit


# -- the pre-kernelization path, reconstructed for A/B timing ---------------


def legacy_append(cfg: StoreConfig, store, values):
    """The six-pass write path this PR replaced (see module docstring)."""
    n = cfg.n
    rows = jnp.arange(n, dtype=jnp.int32)
    pool = store.pool
    bs = cfg.block_size
    idx = store.lengths // bs
    pos = store.lengths % bs
    cur_bid = store.tables[rows, idx]
    fresh = cur_bid == NULL_BLOCK
    if cfg.mode is CopyMode.LAZY:
        shared = pool.frozen[jnp.where(cur_bid >= 0, cur_bid, 0)]
    else:
        shared = pool.refcount[jnp.where(cur_bid >= 0, cur_bid, 0)] > 1
    need_copy = (~fresh) & shared
    need_block = fresh | need_copy

    pool, new_bid = pool_lib.alloc_scan(pool, n, commit=need_block)  # pass 1
    src = jnp.where(need_copy, cur_bid, 0)
    copied = pool.data[src]  # pass 2: dense gather of every row
    pool = pool_lib.write_blocks(pool, new_bid, copied, mask=need_copy)  # 3
    pool = pool_lib.sub_refs(pool, jnp.where(need_copy, cur_bid, NULL_BLOCK))  # 4
    bid = jnp.where(need_block, new_bid, cur_bid)
    tables = store.tables.at[rows, idx].set(bid)
    write_bid = jnp.where(bid >= 0, bid, pool.num_blocks)
    data = pool.data.at[write_bid, pos].set(values, mode="drop")  # pass 5
    data = data.at[pool.num_blocks].set(0)
    pool = pool._replace(data=data)
    return store._replace(pool=pool, tables=tables, lengths=store.lengths + 1)


def legacy_clone(cfg: StoreConfig, store, ancestors):
    """Three-pass clone bookkeeping (add_refs / sub_refs / freeze)."""
    lengths = store.lengths[ancestors]
    new_tables = store.tables[ancestors]
    pool = pool_lib.add_refs(store.pool, new_tables)
    pool = pool_lib.sub_refs(pool, store.tables)
    if cfg.mode is CopyMode.LAZY:
        pool = pool_lib.freeze(pool, new_tables)
    return store._replace(pool=pool, tables=new_tables, lengths=lengths)


# -- harness ----------------------------------------------------------------


def _time_program(cfg, append_fn, clone_fn, t: int, reps: int):
    """Append-heavy LAZY_SR program: a clone every block boundary, appends
    in between (the paper's motivating resample-every-generation churn).
    Returns (us_per_append, us_per_clone)."""
    rng = np.random.default_rng(0)
    ancs = [
        jnp.asarray(rng.integers(0, cfg.n, cfg.n).astype(np.int32))
        for _ in range(t // cfg.block_size + 1)
    ]
    vals = jnp.ones((cfg.n,), jnp.float32)

    def program():
        s = store_lib.create(cfg)
        n_app = n_cl = 0
        app_s = cl_s = 0.0
        for step in range(t):
            if step and step % cfg.block_size == 0:
                t0 = time.time()
                s = clone_fn(cfg, s, ancs[step // cfg.block_size])
                jax.block_until_ready(s.lengths)
                cl_s += time.time() - t0
                n_cl += 1
            t0 = time.time()
            s = append_fn(cfg, s, vals)
            jax.block_until_ready(s.lengths)
            app_s += time.time() - t0
            n_app += 1
        return app_s / n_app, cl_s / max(n_cl, 1)

    program()  # warmup/compile
    out = [program() for _ in range(reps)]
    return (
        float(np.median([a for a, _ in out])),
        float(np.median([c for _, c in out])),
    )


def _model_rows(cfg: StoreConfig, suffix: str):
    """Roofline byte/pass model rows for one config (host-independent)."""
    item_bytes = 4
    for d in cfg.item_shape:
        item_bytes *= d
    block_bytes = item_bytes * cfg.block_size
    nb = cfg.pool_blocks
    kw = dict(
        n=cfg.n,
        touched=cfg.n,
        copies=cfg.n // 4,  # post-resampling divergence front
        num_blocks=nb,
        block_bytes=block_bytes,
        item_bytes=item_bytes,
    )
    costs = {p: append_cost(p, **kw) for p in ("legacy", "fused_jnp", "kernel")}
    clones = {
        p: clone_cost(p, table_entries=cfg.n * cfg.max_blocks, num_blocks=nb)
        for p in ("legacy", "fused_jnp", "kernel")
    }
    rows = [
        emit(
            "write",
            f"write_model_{suffix}",
            0.0,
            f"append_bytes_legacy={costs['legacy'].bytes};"
            f"append_bytes_fused_jnp={costs['fused_jnp'].bytes};"
            f"append_bytes_kernel={costs['kernel'].bytes};"
            f"append_passes={costs['legacy'].passes}/"
            f"{costs['fused_jnp'].passes}/{costs['kernel'].passes};"
            f"kernel_vs_legacy={costs['kernel'].speedup_over(costs['legacy']):.2f}x;"
            f"clone_bytes={clones['legacy'].bytes}/{clones['kernel'].bytes};"
            f"clone_passes={clones['legacy'].passes}/{clones['kernel'].passes}",
            n=cfg.n,
            block_size=cfg.block_size,
            pool_blocks=nb,
        )
    ]
    return rows, costs, clones


def run(quick: bool = False, reps: int = 3, t: int = 32):
    rows = []
    sizes = [(256, 4)] if quick else [(256, 4), (1024, 4), (1024, 16)]
    for n, bs in sizes:
        cfg = StoreConfig(
            mode=CopyMode.LAZY_SR,
            n=n,
            block_size=bs,
            max_blocks=-(-t // bs),
        )
        append_new = jax.jit(store_lib.append, static_argnums=0)
        clone_new = jax.jit(store_lib.clone, static_argnums=0)
        append_old = jax.jit(legacy_append, static_argnums=0)
        clone_old = jax.jit(legacy_clone, static_argnums=0)
        app_new, cl_new = _time_program(cfg, append_new, clone_new, t, reps)
        app_old, cl_old = _time_program(cfg, append_old, clone_old, t, reps)
        rows.append(
            emit(
                "write",
                f"write_append_N{n}_bs{bs}",
                app_new,
                f"legacy_us={app_old * 1e6:.0f};"
                f"speedup={app_old / max(app_new, 1e-9):.2f}x;"
                f"pool_blocks={cfg.pool_blocks};T={t}",
                n=n,
                block_size=bs,
            )
        )
        rows.append(
            emit(
                "write",
                f"write_clone_N{n}_bs{bs}",
                cl_new,
                f"legacy_us={cl_old * 1e6:.0f};"
                f"speedup={cl_old / max(cl_new, 1e-9):.2f}x;"
                f"table_entries={n * cfg.max_blocks}",
                n=n,
                block_size=bs,
            )
        )
        mrows, _, _ = _model_rows(cfg, f"N{n}_bs{bs}")
        rows += mrows

    # The acceptance gate (host-independent, asserted even under --quick):
    # at N >= 1024 with the auto-sized pool, the kernel write path must
    # make >= 2x fewer HBM passes per append than the legacy jnp path and
    # strictly reduce bytes moved; at the filter's default COW granularity
    # (block_size=4 — the append-heavy LAZY_SR shape) the byte reduction
    # itself must be >= 2x.  Clone bookkeeping must drop from three passes
    # to one.
    for bs in (4, 16):
        gate = StoreConfig(
            mode=CopyMode.LAZY_SR, n=1024, block_size=bs, max_blocks=-(-64 // bs)
        )
        grows, costs, clones = _model_rows(gate, f"gate_N1024_bs{bs}")
        rows += grows
        assert costs["legacy"].passes >= 2 * costs["kernel"].passes, costs
        assert (
            costs["kernel"].bytes < costs["fused_jnp"].bytes < costs["legacy"].bytes
        ), costs
        if bs == 4:
            assert costs["kernel"].speedup_over(costs["legacy"]) >= 2.0, costs
        assert clones["kernel"].bytes < clones["legacy"].bytes, clones
        assert clones["legacy"].passes >= 2 * clones["kernel"].passes, clones

    # Sub-block delta COW gates (DESIGN.md §3.2, host-independent): a
    # sparse single-element write to a freshly shared full block
    # (dirty_items=0 — the post-fork divergence write) must move >= 2x
    # fewer bytes than the whole-block kernel copy at every
    # block_size >= 8; a dense COW whose mask fills (degenerating the
    # page back to a full block) must never lose to the whole-block copy.
    for bs in (8, 16, 32):
        dcfg = StoreConfig(
            mode=CopyMode.LAZY_SR, n=1024, block_size=bs, max_blocks=-(-64 // bs),
            delta_cow=True,
        )
        item_bytes, block_bytes = 4, 4 * bs
        kw = dict(
            n=dcfg.n, touched=dcfg.n, copies=dcfg.n,
            num_blocks=dcfg.pool_blocks,
            block_bytes=block_bytes, item_bytes=item_bytes,
        )
        whole = append_cost("kernel", **kw)
        sparse = append_cost("kernel", delta=True, dirty_items=0, **kw)
        dense = append_cost("kernel", delta=True, dirty_items=bs - 1, **kw)
        rows.append(
            emit(
                "write",
                f"write_model_delta_bs{bs}",
                0.0,
                f"whole_bytes={whole.bytes};"
                f"sparse_delta_bytes={sparse.bytes};"
                f"dense_delta_bytes={dense.bytes};"
                f"sparse_win={whole.bytes / max(sparse.bytes, 1):.2f}x",
                n=dcfg.n,
                block_size=bs,
            )
        )
        assert sparse.bytes * 2 <= whole.bytes, (bs, sparse, whole)
        assert dense.bytes <= whole.bytes, (bs, dense, whole)

    # Fused resample->clone chain gate (kernels/clone_chain): the fused
    # op reads the tables once where the composed path dispatches three
    # times — >= 1.3x fewer HBM passes (it is 3x) and >= 1.3x fewer
    # bytes per resampling generation.
    nbc = StoreConfig(
        mode=CopyMode.LAZY_SR, n=1024, block_size=4, max_blocks=16
    ).pool_blocks
    comp = chain_cost("fused_jnp", n=1024, table_entries=1024 * 16, num_blocks=nbc)
    fused = chain_cost("kernel", n=1024, table_entries=1024 * 16, num_blocks=nbc)
    rows.append(
        emit(
            "write",
            "write_model_chain_N1024",
            0.0,
            f"composed_bytes={comp.bytes};fused_bytes={fused.bytes};"
            f"composed_passes={comp.passes};fused_passes={fused.passes};"
            f"fused_win={fused.speedup_over(comp):.2f}x",
            n=1024,
            block_size=4,
        )
    )
    assert comp.passes >= 1.3 * fused.passes, (comp, fused)
    assert comp.bytes >= 1.3 * fused.bytes, (comp, fused)

    # Wall-clock delta-vs-whole and fused-vs-composed rows (jnp fallback
    # on CPU hosts — indicative; the model gates above are the contract).
    for n, bs in [(256, 8)] if quick else [(256, 8), (1024, 8)]:
        base = dict(
            mode=CopyMode.LAZY_SR, n=n, block_size=bs, max_blocks=-(-t // bs)
        )
        cfg_w = StoreConfig(**base)
        cfg_d = StoreConfig(**base, delta_cow=True)
        append_j = jax.jit(store_lib.append, static_argnums=0)
        clone_j = jax.jit(store_lib.clone, static_argnums=0)
        chain_j = jax.jit(store_lib.clone_chain, static_argnums=0)
        key0, logw0 = jax.random.PRNGKey(0), jnp.zeros((n,))

        def chain_fn(cfg, s, _anc):
            s, _ = chain_j(cfg, s, key0, logw0)
            return s

        def composed_fn(cfg, s, _anc):
            return clone_j(cfg, s, resampling.resample_systematic(key0, logw0))

        app_w, cl_comp = _time_program(cfg_w, append_j, composed_fn, t, reps)
        app_d, cl_fused = _time_program(cfg_d, append_j, chain_fn, t, reps)
        rows.append(
            emit(
                "write",
                f"write_append_delta_N{n}_bs{bs}",
                app_d,
                f"whole_us={app_w * 1e6:.0f};T={t}",
                n=n,
                block_size=bs,
            )
        )
        rows.append(
            emit(
                "write",
                f"write_chain_N{n}_bs{bs}",
                cl_fused,
                f"composed_us={cl_comp * 1e6:.0f};T={t}",
                n=n,
                block_size=bs,
            )
        )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
