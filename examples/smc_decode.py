"""Population-based LM decoding on the COW-paged KV cache.

This is the paper's motivating pattern running inside a serving stack —
and the framework's end-to-end serving driver: a small decoder LM serves
a *population* of N continuations with batched requests; resampling
forks KV lineages with zero copying (refcount bookkeeping only); appends
copy-on-write one tail page per diverging lineage.

Run:  PYTHONPATH=src python examples/smc_decode.py [--particles 32]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models.model import LanguageModel
from repro.serving.smc_decode import SMCDecoder

ap = argparse.ArgumentParser()
ap.add_argument("--particles", type=int, default=32)
ap.add_argument("--steps", type=int, default=48)
ap.add_argument("--prompt-len", type=int, default=12)
ap.add_argument("--target-temp", type=float, default=0.5)
args = ap.parse_args()

key = jax.random.PRNGKey(0)
cfg = smoke_config("musicgen_large")  # small decoder backbone
lm = LanguageModel(cfg)
params, _ = lm.init(key)

print(f"model: {cfg.name} (reduced) — {cfg.n_layers}L d{cfg.d_model}")
print(f"population: {args.particles} particles, {args.steps} tokens, "
      f"target temperature {args.target_temp}")

dec = SMCDecoder(
    lm, params,
    n_particles=args.particles,
    max_len=args.prompt_len + args.steps + 16,
    target_temp=args.target_temp,
    block_size=4,
)
prompt = jax.random.randint(key, (args.prompt_len,), 0, cfg.vocab_size)

t0 = time.time()
res = dec.run(key, prompt, steps=args.steps)
dt = time.time() - t0

dense = dec.dense_equivalent_blocks(args.steps, args.prompt_len)
peak = int(np.max(np.asarray(res.used_blocks_trace)))
print(f"\ndecoded {args.particles}x{args.steps} tokens in {dt:.1f}s "
      f"({dt / args.steps * 1e3:.0f} ms/step incl. compile)")
print(f"resampling events: {int(res.resampled.sum())} "
      f"(each forked {args.particles} KV lineages with ZERO copying)")
print(f"peak KV blocks:    {peak}  vs dense per-sequence caches: {dense} "
      f"({dense / peak:.2f}x saving)")
print(f"log evidence:      {float(res.log_evidence):.2f}")
print(f"final ESS:         {float(res.ess_trace[-1]):.1f} / {args.particles}")
best = int(jnp.argmax(res.log_weights))
print(f"best continuation: {np.asarray(res.tokens[best])[:16]} ...")
