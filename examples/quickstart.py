"""Quickstart: the paper's platform in five minutes.

1. The object-graph semantics (paper Section 2): lazy deep copies,
   copy-on-write, and the Table 2 cross-reference case.
2. The array-world platform: a particle filter whose storage strategy is
   a config switch — identical outputs, very different memory.

Run:  PYTHONPATH=src python examples/quickstart.py

``REPRO_QS_N`` / ``REPRO_QS_T`` shrink the particle filter (CI smoke
runs N=64, T=16 so the documented entry point can't rot unnoticed).
"""

import math
import os
import time

import jax

from repro.core.config import ALL_MODES, CopyMode
from repro.core.graph import Runtime
from repro.smc.filters import FilterConfig, ParticleFilter, SSMDef

print("=" * 72)
print("1. Object-graph lazy copies (paper Section 2, Tables 1-2)")
print("=" * 72)

rt = Runtime(CopyMode.LAZY_SR)
x1 = rt.new(value=1)
rt.write_new(x1, "next", value=2)

x2 = rt.deep_copy(x1)  # O(1): a label and an edge, no payload copied
print(f"after deep_copy:        payload copies = {rt.stats.payload_copies}")

_ = rt.read(x2, "value")  # reads don't copy
print(f"after read:             payload copies = {rt.stats.payload_copies}")

rt.write(x2, "value", 10)  # first write copies exactly one node
print(f"after write:            payload copies = {rt.stats.payload_copies}")
print(f"original intact:        x1.value = {rt.read(x1, 'value')}")
print(f"copy diverged:          x2.value = {rt.read(x2, 'value')}")

# Table 2: cross reference -> eager finish, correct result
rt2 = Runtime(CopyMode.LAZY_SR)
a1 = rt2.new(value=1)
a2 = rt2.deep_copy(a1)
rt2.write(a2, "value", 2)
rt2.write(a2, "next", a1)  # cross reference
a3 = rt2.deep_copy(a2)
rt2.write(a3, "value", 3)
y3 = rt2.read(a3, "next")
print(f"Table 2 cross-reference case prints {rt2.read(y3, 'value')} (paper: 1)")

print()
print("=" * 72)
print("2. Particle filter: one code path, three storage strategies")
print("=" * 72)

A, Q, R = 0.9, 0.5, 0.3


def lgssm() -> SSMDef:
    def init(key, n, params):
        return jax.random.normal(key, (n,))

    def step(key, x, t, y_t, params):
        x = A * x + math.sqrt(Q) * jax.random.normal(key, x.shape)
        logw = -0.5 * ((y_t - x) ** 2 / R + math.log(2 * math.pi * R))
        return x, logw, x[:, None]

    return SSMDef(init=init, step=step, record_shape=(1,))


key = jax.random.PRNGKey(0)
N = int(os.environ.get("REPRO_QS_N", "256"))
T = int(os.environ.get("REPRO_QS_T", "64"))
ys = jax.random.normal(key, (T,))  # any observations will do here

for mode in ALL_MODES:
    cfg = FilterConfig(n_particles=N, n_steps=T, mode=mode, block_size=1)
    pf = ParticleFilter(lgssm(), cfg)
    fn = pf.jitted()
    res = fn(key, None, ys)  # compile + run
    jax.block_until_ready(res.log_evidence)
    t0 = time.time()
    res = fn(key, None, ys)
    jax.block_until_ready(res.log_evidence)
    dt = time.time() - t0
    print(
        f"{mode.value:<8} logZ={float(res.log_evidence):9.3f}  "
        f"peak_memory={int(res.store.peak_blocks):6d} items  "
        f"(dense would be {N * T})  time={dt * 1e3:.1f} ms"
    )

print()
print(f"sparse bound t + 6 N log N = {T + 6 * N * math.log(N):.0f} items")
print("identical logZ across modes = the paper's correctness check;")
print("the lazy modes' peak memory follows the sparse bound, not N*T.")
