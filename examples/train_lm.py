"""End-to-end training driver example.

Trains an assigned-architecture model on the synthetic Markov corpus
with the full substrate: data pipeline -> jitted train step (loss, grads,
clipping, AdamW) -> async checkpoints -> crash-idempotent resume.

CPU-friendly default: the reduced mamba2 config (~100k params) for 300
steps — loss visibly approaches the corpus entropy floor in ~a minute.
``--arch mamba2_130m --full`` trains the real 130M-parameter config
(sized for a TPU host; identical code path, and the same step function
the multi-pod dry-run compiles for the 256-chip mesh).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
Resume after a crash: just run the same command again.
"""

import argparse

from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="mamba2_130m")
ap.add_argument("--full", action="store_true")
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq-len", type=int, default=128)
args = ap.parse_args()

model_cfg = get_config(args.arch) if args.full else smoke_config(args.arch)
data_cfg = DataConfig(
    vocab_size=model_cfg.vocab_size, seq_len=args.seq_len,
    global_batch=args.batch,
)
trainer = Trainer(
    model_cfg,
    data_cfg,
    AdamWConfig(learning_rate=3e-3, warmup_steps=20, total_steps=args.steps),
    TrainConfig(
        total_steps=args.steps,
        log_every=20,
        checkpoint_every=100,
        checkpoint_dir=f"checkpoints/example_{args.arch}",
    ),
)
history = trainer.run()
floor = trainer.data.entropy_rate
print(f"\nloss {history['loss'][0]:.3f} -> {history['loss'][-1]:.3f} "
      f"(corpus entropy floor {floor:.3f} nats/token)")
