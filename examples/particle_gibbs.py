"""Particle Gibbs on the VBD model — the paper's eager-copy case.

The retained reference trajectory is deep-copied *eagerly* between
iterations (it must outlive the population — outside the tree pattern),
exactly the note in the paper's Section 4 for its VBD experiment.

Run:  PYTHONPATH=src python examples/particle_gibbs.py
"""

import time

import jax
import numpy as np

from repro.smc.filters import FilterConfig
from repro.smc.pgibbs import ParticleGibbs
from repro.smc.programs import vbd

key = jax.random.PRNGKey(0)
T, N, ITERS = 60, 256, 3

ssm, params = vbd.build()
obs = vbd.gen_data(key, T)
print(f"VBD (SEIR/SEI) dengue-style outbreak: T={T} weeks of case counts")
print(f"particle Gibbs: N={N}, {ITERS} iterations "
      f"(paper: N=4096, T=182, 3 iterations)")

pg = ParticleGibbs(ssm, FilterConfig(n_particles=N, n_steps=T))
t0 = time.time()
out = pg.run(key, params, obs, n_iters=ITERS)
print(f"\nran in {time.time() - t0:.1f}s")
print(f"log-evidence per iteration: "
      f"{[f'{z:.1f}' for z in np.asarray(out.log_evidences)]}")
print(f"peak store blocks: {int(out.peak_blocks)} "
      f"(dense equivalent {N * T // 4})")
ref = np.asarray(out.reference)
print(f"retained trajectory (eagerly copied): shape {ref.shape}")
print(f"final infected (Ih) along the reference: " f"{ref[:: T // 6, 2].round(1)}")
